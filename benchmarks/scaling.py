"""Data-parallel scaling microbenchmark for mesh-sharded GAN programs.

Times the ahead-of-time compiled generator executable at a fixed
*global* batch under three frozen meshes — single-device, ``(2, 1)``
and ``(4, 1)`` — over forced host CPU devices, and emits

* ``micro/<model>/dp_scaling_{1,2,4}x_us`` — wall-clock per ``apply``
  at global batch 8 on 1/2/4 data-parallel devices.  Only the ``1x``
  row gates (widened: it is the same executable ``program_us`` already
  tracks, plus nothing); the multi-device rows are **informational on
  CPU** — forced host devices share the same cores, so DP "scaling"
  here measures partitioning overhead, not speedup;
* ``micro/<model>/dp_speedup`` — ``1x`` / ``4x`` wall-clock ratio
  (informational; > 1 only on machines with real parallel hardware).

Runs **standalone** (never imported by ``benchmarks/run.py``): the
device-forcing ``XLA_FLAGS`` must be set before jax first initializes,
and the aggregator's process has long since locked its single real CPU
device.  Instead of returning rows to the aggregator it merges its
pivoted rows into ``BENCH_dataflow.json`` itself (CI runs it right
after ``run.py``)::

    PYTHONPATH=src python benchmarks/scaling.py --models dcgan
"""

from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import sys
import time

# Must precede the first jax initialization: the host platform device
# count locks at first init (same constraint as launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))

import jax

DEFAULT_BATCH = 8
DEFAULT_REPEATS = 30

# (row label, (data, model) mesh); None = plain single-device program.
MESHES = (("1x", None), ("2x", (2, 1)), ("4x", (4, 1)))


def _time_apply(prog, params, z, repeats: int) -> float:
    """Steady-state µs per ``apply`` (first call pays trace+compile and
    is excluded)."""
    out = prog.apply(params, z)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = prog.apply(params, z)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def run_scaling(models=("dcgan",), channel_scale=0.25,
                batch=DEFAULT_BATCH, repeats=DEFAULT_REPEATS, seed=0):
    from repro.models.gan import GanConfig, init_gan
    from repro.program import Program

    rows = []
    print(f"\n== dp scaling: generator program at global batch {batch} "
          f"over {len(jax.devices())} forced devices "
          f"(channels×{channel_scale}) ==")
    for name in models:
        cfg = GanConfig(name=name, channel_scale=channel_scale)
        g_params, _ = init_gan(cfg, jax.random.PRNGKey(seed))
        z = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (batch, cfg.z_dim))
        times = {}
        for label, mesh in MESHES:
            prog = Program.build(cfg, batch, mesh=mesh,
                                 differentiable=False)
            zp = z if prog.input_sharding is None else \
                jax.device_put(z, prog.input_sharding)
            us = _time_apply(prog, g_params, zp, repeats)
            times[label] = us
            gate = "gated wide" if label == "1x" else \
                "informational on CPU"
            rows.append((f"micro/{name}/dp_scaling_{label}_us", us,
                         f"mesh={prog.mesh_str}, {gate}"))
        speedup = times["1x"] / times["4x"] if times["4x"] > 0 \
            else float("inf")
        rows.append((f"micro/{name}/dp_speedup", speedup,
                     "1x/4x wall-clock, informational on CPU"))
        print(f"  {name:8s} 1x={times['1x']:9.1f}us  "
              f"2x={times['2x']:9.1f}us  4x={times['4x']:9.1f}us  "
              f"dp_speedup={speedup:5.2f}x")
    return rows


def merge_into_artifact(rows, path) -> None:
    """Pivot ``micro/<model>/<metric>`` rows and merge them into the
    (possibly already written) ``BENCH_dataflow.json`` — the aggregator
    ran in another process, so this is a read-modify-write, not a
    return value."""
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    for name, value, _ in rows:
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "micro":
            continue
        v = float(value)
        doc.setdefault(parts[1], {})[parts[2]] = \
            v if math.isfinite(v) else None
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"merged {len(rows)} rows into {path}")


def main(argv=None):
    from repro.configs.gans import GAN_MODELS

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--models", nargs="+", default=["dcgan"],
                    choices=sorted(GAN_MODELS))
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--repeats", type=int, default=DEFAULT_REPEATS)
    ap.add_argument("--channel-scale", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(_ROOT / "BENCH_dataflow.json"))
    args = ap.parse_args(argv)
    rows = run_scaling(models=tuple(args.models), batch=args.batch,
                       channel_scale=args.channel_scale,
                       repeats=args.repeats, seed=args.seed)
    merge_into_artifact(rows, args.out)

    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
    return rows


if __name__ == "__main__":
    main()
