"""Roofline table from the dry-run artifacts (§Roofline deliverable)."""

from __future__ import annotations

from repro.utils.roofline import load_rows


def render(rows, title="Roofline (per device, TPU v5e constants)"):
    print(f"\n== {title} ==")
    hdr = (f"{'arch':24s} {'shape':11s} {'mesh':8s} {'compute':>9s} "
           f"{'memory':>9s} {'coll':>9s} {'dcn':>9s} {'bound':>10s} "
           f"{'useful':>7s} {'mfu≤':>6s} {'tempGB':>7s}")
    print(hdr)
    out = []
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.mesh)):
        if r.status != "ok":
            print(f"{r.arch:24s} {r.shape:11s} {r.mesh:8s} "
                  f"SKIP: {r.reason}")
            out.append((f"roofline/{r.arch}/{r.shape}/{r.mesh}", 0.0,
                        f"skip: {r.reason}"))
            continue
        print(f"{r.arch:24s} {r.shape:11s} {r.mesh:8s} "
              f"{r.compute_s:9.4f} {r.memory_s:9.4f} "
              f"{r.collective_s:9.4f} {r.dcn_s:9.4f} "
              f"{r.dominant:>10s} {r.useful_ratio:7.2f} "
              f"{r.mfu_bound:6.2f} {r.temp_gb:7.1f}")
        out.append((f"roofline/{r.arch}/{r.shape}/{r.mesh}/mfu_bound",
                    r.mfu_bound, f"dominant={r.dominant}"))
    return out


def run_all():
    rows = load_rows()
    if not rows:
        print("\n== Roofline: no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all` first ==")
        return [("roofline/missing", 0.0, "no artifacts")]
    return render(rows)


if __name__ == "__main__":
    run_all()
