"""Wall-clock microbenchmarks (CPU, XLA-compiled): GANAX dataflow vs the
zero-insertion baseline on the paper's layer geometries.

The zero-elimination speedup is algorithmic, so it shows up even on CPU:
the GANAX path executes only consequential MACs.  (Kernel-level VMEM/MXU
effects require real TPU hardware; the interpret-mode Pallas kernel is
validated for correctness in tests/, not timed here.)

Runnable directly with the same knobs the tuner and CI use::

    PYTHONPATH=src python benchmarks/microbench.py \
        --backends polyphase zero-insert --repeats 5 --models dcgan

``--backends`` accepts any registered dataflow backend plus ``auto``
(planner-consulting dispatch — tuned when a plan file is warm, heuristic
otherwise).  The default model pool includes ``3dgan`` so the artifacts
track the volumetric trajectory now that the Pallas kernel covers 3-D;
its wall-clock rows feed the CI regression gate like every other model.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gans import GAN_MODELS
from repro.core.dataflow import (DataflowPolicy, Epilogue,
                                 available_backends, tconv,
                                 uop_cache_info)
from repro.core.tconv import tconv_output_shape

DEFAULT_BACKENDS = ("polyphase", "zero-insert")


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_dataflows(models=("dcgan", "3dgan"), batch=2, channel_scale=0.25,
                    backends=DEFAULT_BACKENDS, repeats=5):
    """Per-model generator tconv wall-clock for each requested backend.

    Emits ``micro/<model>/<backend>_us`` per backend (dashes become
    underscores), the legacy ``ganax_us`` alias for the polyphase path,
    and ``wallclock_speedup`` (zero-insert / polyphase) when both are in
    the pool — the row names `BENCH_dataflow.json` tracks across PRs."""
    rows = []
    cache0 = uop_cache_info()
    print("\n== microbench: dataflow backends "
          f"{list(backends)} (batch={batch}, channels×{channel_scale}) ==")
    for name in models:
        g_layers, _ = GAN_MODELS[name]
        totals = dict.fromkeys(backends, 0.0)
        for l in g_layers:
            if not l.transposed:
                continue
            cin = max(1, int(l.cin * channel_scale))
            cout = max(1, int(l.cout * channel_scale))
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(batch, *l.in_spatial, cin)),
                            jnp.float32)
            w = jnp.asarray(rng.normal(
                size=(*l.kernel, cin, cout)), jnp.float32)
            for backend in backends:
                policy = DataflowPolicy(backend=backend)
                f = jax.jit(lambda x, w, l=l, policy=policy: tconv(
                    x, w, l.strides, l.paddings, policy=policy))
                totals[backend] += _time(f, x, w, iters=repeats)
        summary = "  ".join(f"{b}={totals[b]*1e3:7.2f}ms"
                            for b in backends)
        for backend in backends:
            rows.append((f"micro/{name}/{backend.replace('-', '_')}_us",
                         totals[backend] * 1e6, ""))
        if "polyphase" in totals:
            rows.append((f"micro/{name}/ganax_us",
                         totals["polyphase"] * 1e6, "alias of polyphase"))
        if "polyphase" in totals and "zero-insert" in totals:
            speed = totals["zero-insert"] / totals["polyphase"] \
                if totals["polyphase"] else float("nan")
            rows.append((f"micro/{name}/wallclock_speedup", speed,
                         "zero-elimination, measured"))
            summary += f"  speedup={speed:4.2f}x"
        print(f"  {name:8s} {summary}")
    info = uop_cache_info()
    print(f"  μop cache: {info['hits'] - cache0['hits']} hits / "
          f"{info['misses'] - cache0['misses']} misses (this bench)")
    return rows


def bench_fused_epilogue(models=("dcgan", "3dgan"), batch=2,
                         channel_scale=0.25, repeats=5,
                         backend="polyphase"):
    """Fused (in-dispatch) vs unfused (out-of-op ``+ b`` / activation)
    epilogue wall-clock over each model's generator tconv layers.

    Emits ``micro/<model>/fused_us`` / ``unfused_us`` and the
    machine-relative ``fused_speedup`` (unfused / fused — both sides
    from the same run).  ``fused_us`` feeds the CI regression gate; on
    the pure-JAX backend runnable in CI the two formulations compile to
    near-identical fused XLA, so the gated expectation is "no
    regression", with the HBM-round-trip win reserved for real-TPU
    kernel runs."""
    rows = []
    ep = Epilogue(bias=True, activation="relu")
    policy = DataflowPolicy(backend=backend)
    print(f"\n== microbench: fused vs unfused epilogue ({backend}, "
          f"batch={batch}, channels×{channel_scale}) ==")
    for name in models:
        g_layers, _ = GAN_MODELS[name]
        fused_total = unfused_total = 0.0
        for l in g_layers:
            if not l.transposed:
                continue
            cin = max(1, int(l.cin * channel_scale))
            cout = max(1, int(l.cout * channel_scale))
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(batch, *l.in_spatial, cin)),
                            jnp.float32)
            w = jnp.asarray(rng.normal(size=(*l.kernel, cin, cout)),
                            jnp.float32)
            b = jnp.asarray(rng.normal(size=(cout,)), jnp.float32)
            fused = jax.jit(lambda x, w, b, l=l: tconv(
                x, w, l.strides, l.paddings, policy=policy, bias=b,
                epilogue=ep))
            unfused = jax.jit(lambda x, w, b, l=l: jax.nn.relu(tconv(
                x, w, l.strides, l.paddings, policy=policy) + b))
            fused_total += _time(fused, x, w, b, iters=repeats)
            unfused_total += _time(unfused, x, w, b, iters=repeats)
        speed = unfused_total / fused_total if fused_total \
            else float("nan")
        rows.append((f"micro/{name}/fused_us", fused_total * 1e6, ""))
        rows.append((f"micro/{name}/unfused_us", unfused_total * 1e6, ""))
        rows.append((f"micro/{name}/fused_speedup", speed,
                     "unfused/fused, machine-relative"))
        print(f"  {name:8s} fused={fused_total*1e3:7.2f}ms  "
              f"unfused={unfused_total*1e3:7.2f}ms  "
              f"ratio={speed:4.2f}x")
    return rows


def bench_program(models=("dcgan", "3dgan"), batch=2, channel_scale=0.25,
                  repeats=5, backend="polyphase"):
    """Ahead-of-time compiled Program vs the legacy per-call dispatch
    threading over each model's full generator forward.

    Emits ``micro/<model>/program_us`` (the Program API's jitted
    executable — this row feeds the CI regression gate: the supported
    entry point must not regress), ``generator_apply_us`` (the
    legacy-wrapper path, now itself program-backed), and the
    machine-relative ``program_speedup`` (legacy / program, both sides
    from the same run)."""
    from repro.models.gan import GanConfig, generator_apply, init_gan
    from repro.program import Program

    rows = []
    print(f"\n== microbench: program vs legacy dispatch ({backend}, "
          f"batch={batch}, channels×{channel_scale}) ==")
    for name in models:
        cfg = GanConfig(name=name, channel_scale=channel_scale,
                        backend=backend)
        g_params, _ = init_gan(cfg, jax.random.PRNGKey(0))
        z = jnp.asarray(np.random.default_rng(0).normal(
            size=(batch, cfg.z_dim)), jnp.float32)
        prog = Program.build(cfg, batch, "generator")
        legacy = jax.jit(lambda p, z, cfg=cfg: generator_apply(p, z, cfg))
        t_prog = _time(prog.apply, g_params, z, iters=repeats)
        t_leg = _time(legacy, g_params, z, iters=repeats)
        speed = t_leg / t_prog if t_prog else float("nan")
        rows.append((f"micro/{name}/program_us", t_prog * 1e6, ""))
        rows.append((f"micro/{name}/generator_apply_us", t_leg * 1e6,
                     "legacy wrapper"))
        rows.append((f"micro/{name}/program_speedup", speed,
                     "legacy/program, machine-relative"))
        print(f"  {name:8s} program={t_prog*1e3:7.2f}ms  "
              f"legacy={t_leg*1e3:7.2f}ms  ratio={speed:4.2f}x")
    return rows


def bench_precision(models=("dcgan", "3dgan"), batch=2,
                    channel_scale=0.25, repeats=5,
                    backend="polyphase"):
    """Storage-precision rows (repro.quant): the bf16 generator
    executable and the int8-weight deploy path, plus analytic HBM
    traffic at each precision.

    Emits per model:

    * ``generator_bf16_us`` — the full generator forward with
      ``dtype="bfloat16"`` storage (f32 accumulation inside the op).
      Gated in CI like ``program_us``: the low-precision path must not
      regress.  On CPU XLA bf16 is usually emulated, so the row tracks
      "does the bf16 program stay runnable and sane", not a memory-BW
      win — that is what the analytic byte rows are for.
    * ``generator_int8_us`` — the int8-weight export served end to end:
      ``quantize_program`` → JSON round-trip → ``Program`` (weights
      dequantized into bf16 storage at load) → forward (informational).
    * ``hbm_bytes_f32`` / ``hbm_bytes_bf16`` / ``hbm_bytes_int8`` —
      analytic per-forward HBM traffic (weights + biases + layer
      in/out activations, batch included): storage itemsize per
      element, except int8 weights at 1 B + one f32 scale per output
      channel, and biases always f32 (the accumulator precision).
      Deterministic (no timing), informational — they document the
      compression the storage dtype buys on a memory-bound forward."""
    import json as _json

    from repro.models.gan import GanConfig, init_gan
    from repro.program import Program
    from repro.program.spec import ProgramSpec
    from repro.quant import quantize_program, storage_itemsize

    rows = []
    print(f"\n== microbench: storage precision ({backend}, "
          f"batch={batch}, channels×{channel_scale}) ==")
    for name in models:
        cfg32 = GanConfig(name=name, channel_scale=channel_scale,
                          backend=backend)
        cfgbf = GanConfig(name=name, channel_scale=channel_scale,
                          backend=backend, dtype="bfloat16")
        g_params, _ = init_gan(cfg32, jax.random.PRNGKey(0))
        z = jnp.asarray(np.random.default_rng(0).normal(
            size=(batch, cfg32.z_dim)), jnp.float32)

        prog_bf = Program.build(cfgbf, batch, "generator")
        t_bf = _time(prog_bf.apply, g_params, z, iters=repeats)
        rows.append((f"micro/{name}/generator_bf16_us", t_bf * 1e6,
                     "bf16 storage, f32 accumulation; gated"))

        # int8 deploy: export → JSON round-trip → dequantize-at-load
        spec_q = ProgramSpec.from_json(_json.loads(_json.dumps(
            quantize_program(prog_bf.spec, g_params).to_json())))
        prog_q = Program(spec_q)
        t_q = _time(prog_q.apply, prog_q.params, z, iters=repeats)
        rows.append((f"micro/{name}/generator_int8_us", t_q * 1e6,
                     "int8-weight export served (informational)"))

        # analytic HBM traffic per forward at each precision
        g_layers, _ = cfgbf.layers
        for label, wsize, asize, int8 in (("f32", 4, 4, False),
                                          ("bf16", 2, 2, False),
                                          ("int8", 1, 2, True)):
            total = 0
            for l in g_layers:
                taps = int(np.prod(np.asarray(l.kernel)))
                w_el = taps * l.cin * l.cout
                total += w_el * (1 if int8 else wsize)
                if int8:
                    total += 4 * l.cout            # per-channel scales
                total += 4 * l.cout                # bias, always f32
                out_sp = tconv_output_shape(
                    (batch, *l.in_spatial, l.cin),
                    (*l.kernel, l.cin, l.cout), l.strides, l.paddings
                )[1:-1] if l.transposed else l.conv_out_spatial()
                total += batch * asize * (
                    int(np.prod(np.asarray(l.in_spatial))) * l.cin +
                    int(np.prod(np.asarray(out_sp))) * l.cout)
            rows.append((f"micro/{name}/hbm_bytes_{label}", float(total),
                         "analytic per-forward traffic"))
        f32b = rows[-3][1]
        print(f"  {name:8s} bf16={t_bf*1e3:7.2f}ms  int8={t_q*1e3:7.2f}ms"
              f"  bytes f32={f32b/1e6:6.2f}MB"
              f"  bf16={rows[-2][1]/1e6:6.2f}MB"
              f"  int8={rows[-1][1]/1e6:6.2f}MB")
    assert storage_itemsize("bfloat16") == 2   # the asize=2 rows above
    return rows


def bench_obs_overhead(models=("dcgan",), batch=2,
                       channel_scale=0.25, repeats=5,
                       backend="polyphase"):
    """Cost of the obs instrumentation on the ``Program.apply`` hot
    path: the instrumented wrapper vs the raw jitted callable
    (``prog._apply``), timed interleaved so both sides share every noise
    window and reduced with the per-thunk *minimum* — the wrapper delta
    is sub-microsecond on a millisecond-scale op, so a median is still
    noise-dominated on a contended host while the min (noise is
    strictly additive) recovers both sides' intrinsic time.  Only the
    *fastest* program is measured by default: the wrapper delta is a
    fixed per-call cost, so the quickest apply gives the tightest
    relative bound, while on a hundreds-of-ms program the same delta is
    thousands of times smaller than run-to-run drift — that row could
    only ever flake, never inform.

    Emits ``micro/<model>/obs_overhead_pct`` — the **disabled**-tracing
    wrapper cost, clamped at 0 and gated in CI against an absolute cap
    (observability must stay near-free when off) — plus the
    informational ``obs_enabled_overhead_pct`` (tracing on, in-memory
    sink: the price of actually recording spans)."""
    from repro import obs
    from repro.models.gan import GanConfig, init_gan
    from repro.program import Program
    from repro.tune.measure import time_interleaved

    rows = []
    print(f"\n== microbench: obs overhead on program apply ({backend}, "
          f"batch={batch}, channels×{channel_scale}) ==")
    was_enabled, prior_sink = obs.is_enabled(), obs.get_sink()
    rounds = max(repeats * 3, 15)   # min over many rounds: noise floor
    try:
        for name in models:
            cfg = GanConfig(name=name, channel_scale=channel_scale,
                            backend=backend)
            g_params, _ = init_gan(cfg, jax.random.PRNGKey(0))
            z = jnp.asarray(np.random.default_rng(0).normal(
                size=(batch, cfg.z_dim)), jnp.float32)
            prog = Program.build(cfg, batch, "generator")
            thunks = [lambda: prog.apply(g_params, z),
                      lambda: prog._apply(g_params, z)]
            obs.disable()
            t_off, t_raw = time_interleaved(thunks, warmup=1,
                                            repeats=rounds, reduce="min")
            obs.enable()    # fresh in-memory sink
            t_on, t_raw_on = time_interleaved(thunks, warmup=1,
                                              repeats=rounds,
                                              reduce="min")
            obs.disable()
            off_pct = max(0.0, (t_off / t_raw - 1.0) * 100.0) \
                if t_raw else 0.0
            on_pct = max(0.0, (t_on / t_raw_on - 1.0) * 100.0) \
                if t_raw_on else 0.0
            rows.append((f"micro/{name}/obs_overhead_pct", off_pct,
                         "apply wrapper vs raw callable, tracing off; "
                         "gated: absolute cap"))
            rows.append((f"micro/{name}/obs_enabled_overhead_pct", on_pct,
                         "tracing on, memory sink (informational)"))
            print(f"  {name:8s} raw={t_raw*1e6:8.1f}us  "
                  f"disabled=+{off_pct:4.2f}%  enabled=+{on_pct:4.2f}%")
    finally:
        if was_enabled:
            obs.enable(prior_sink)
        else:
            obs.disable()
    return rows


def bench_kernel_interpret():
    """Sanity timing of the Pallas kernel in interpret mode — both the
    planar and the volumetric (3-D) entry points (correctness path; not
    a perf number)."""
    rng = np.random.default_rng(0)
    policy = DataflowPolicy(backend="pallas-interpret")
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4, 128, 128)), jnp.float32)
    t0 = time.perf_counter()
    jax.block_until_ready(tconv(x, w, (2, 2), (1, 1), policy=policy))
    dt = time.perf_counter() - t0
    print(f"\n  pallas-interpret tconv 8x8x128→16x16x128: {dt*1e3:.1f}ms "
          "(correctness path)")
    x3 = jnp.asarray(rng.normal(size=(1, 4, 4, 4, 32)), jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(4, 4, 4, 32, 32)), jnp.float32)
    t0 = time.perf_counter()
    jax.block_until_ready(tconv(x3, w3, (2, 2, 2), (1, 1, 1),
                                policy=policy))
    dt3 = time.perf_counter() - t0
    print(f"  pallas-interpret tconv3d 4³x32→8³x32: {dt3*1e3:.1f}ms "
          "(correctness path)")
    return [("micro/pallas_interpret_us", dt * 1e6, "interpret mode"),
            ("micro/pallas_interpret_3d_us", dt3 * 1e6,
             "interpret mode, volumetric")]


def run_all(models=("dcgan", "3dgan"), batch=2, channel_scale=0.25,
            backends=DEFAULT_BACKENDS, repeats=5):
    rows = bench_dataflows(models, batch, channel_scale,
                           backends=backends, repeats=repeats)
    rows += bench_fused_epilogue(models, batch, channel_scale,
                                 repeats=repeats)
    rows += bench_program(models, batch, channel_scale, repeats=repeats)
    rows += bench_precision(models, batch, channel_scale,
                            repeats=repeats)
    # first model only: the quickest apply bounds the fixed wrapper
    # cost tightest (see bench_obs_overhead)
    rows += bench_obs_overhead(models[:1], batch, channel_scale,
                               repeats=repeats)
    rows += bench_kernel_interpret()
    return rows


def main(argv=None):
    valid = available_backends() + ("auto",)
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--models", nargs="+", default=["dcgan", "3dgan"],
                    choices=sorted(GAN_MODELS))
    ap.add_argument("--backends", nargs="+", default=list(DEFAULT_BACKENDS),
                    choices=sorted(valid),
                    help="dataflow backends to time")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed iterations per layer (mean reported)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--channel-scale", type=float, default=0.25)
    args = ap.parse_args(argv)
    return run_all(models=tuple(args.models), batch=args.batch,
                   channel_scale=args.channel_scale,
                   backends=tuple(args.backends), repeats=args.repeats)


if __name__ == "__main__":
    main()
