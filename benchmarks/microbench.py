"""Wall-clock microbenchmarks (CPU, XLA-compiled): GANAX dataflow vs the
zero-insertion baseline on the paper's layer geometries.

The zero-elimination speedup is algorithmic, so it shows up even on CPU:
the GANAX path executes only consequential MACs.  (Kernel-level VMEM/MXU
effects require real TPU hardware; the interpret-mode Pallas kernel is
validated for correctness in tests/, not timed here.)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gans import GAN_MODELS
from repro.core.dataflow import DataflowPolicy, tconv, uop_cache_info

GANAX = DataflowPolicy(backend="polyphase")
BASELINE = DataflowPolicy(backend="zero-insert")


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_dataflows(models=("dcgan", "3dgan"), batch=2, channel_scale=0.25):
    rows = []
    cache0 = uop_cache_info()
    print("\n== microbench: GANAX vs zero-insertion dataflow "
          f"(batch={batch}, channels×{channel_scale}) ==")
    for name in models:
        g_layers, _ = GAN_MODELS[name]
        tg = tz = 0.0
        for l in g_layers:
            if not l.transposed:
                continue
            cin = max(1, int(l.cin * channel_scale))
            cout = max(1, int(l.cout * channel_scale))
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(batch, *l.in_spatial, cin)),
                            jnp.float32)
            w = jnp.asarray(rng.normal(
                size=(*l.kernel, cin, cout)), jnp.float32)
            f_g = jax.jit(lambda x, w, l=l: tconv(
                x, w, l.strides, l.paddings, policy=GANAX))
            f_z = jax.jit(lambda x, w, l=l: tconv(
                x, w, l.strides, l.paddings, policy=BASELINE))
            tg += _time(f_g, x, w)
            tz += _time(f_z, x, w)
        speed = tz / tg if tg else float("nan")
        rows.append((f"micro/{name}/ganax_us", tg * 1e6, ""))
        rows.append((f"micro/{name}/zero_insert_us", tz * 1e6, ""))
        rows.append((f"micro/{name}/wallclock_speedup", speed,
                     "zero-elimination, measured"))
        print(f"  {name:8s} ganax={tg*1e3:7.2f}ms  zero_insert="
              f"{tz*1e3:7.2f}ms  speedup={speed:4.2f}x")
    info = uop_cache_info()
    print(f"  μop cache: {info['hits'] - cache0['hits']} hits / "
          f"{info['misses'] - cache0['misses']} misses (this bench)")
    return rows


def bench_kernel_interpret():
    """Sanity timing of the Pallas kernel in interpret mode (correctness
    path; not a perf number)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4, 128, 128)), jnp.float32)
    policy = DataflowPolicy(backend="pallas-interpret")
    t0 = time.perf_counter()
    out = tconv(x, w, (2, 2), (1, 1), policy=policy)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"\n  pallas-interpret tconv 8x8x128→16x16x128: {dt*1e3:.1f}ms "
          f"(correctness path)")
    return [("micro/pallas_interpret_us", dt * 1e6, "interpret mode")]


def run_all():
    rows = bench_dataflows()
    rows += bench_kernel_interpret()
    return rows


if __name__ == "__main__":
    run_all()
