"""Benchmark aggregator: one section per paper table/figure + the roofline
table.  Prints ``name,value,derived`` CSV at the end (harness contract)."""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import microbench, paper_figs, roofline
    rows = []
    rows += paper_figs.run_all()
    rows += microbench.run_all()
    rows += roofline.run_all()

    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
