"""Benchmark aggregator: one section per paper table/figure + the roofline
table.  Prints ``name,value,derived`` CSV at the end (harness contract)
and writes ``BENCH_dataflow.json`` (GANAX vs zero-insert wall-clock per
Table-I model) so the perf trajectory is recorded across PRs."""

from __future__ import annotations

import json
import pathlib
import sys

# `python benchmarks/run.py` puts benchmarks/ itself on sys.path, not the
# repo root the `benchmarks.*` imports need — add it.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def _dataflow_json(rows) -> dict:
    """Pivot the micro/<model>/<metric> rows into {model: {metric: value}}.

    Non-finite values (e.g. a NaN speedup when a model has no transposed
    layers) become null — the artifact must stay valid JSON for CI."""
    import math
    out: dict[str, dict[str, float | None]] = {}
    for name, value, _ in rows:
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "micro":
            continue
        v = float(value)
        out.setdefault(parts[1], {})[parts[2]] = \
            v if math.isfinite(v) else None
    return out


def main() -> None:
    from benchmarks import microbench, paper_figs, roofline, traffic
    rows = []
    rows += paper_figs.run_all()
    micro_rows = microbench.run_all()
    # traffic rows share the micro/<model>/<metric> convention so the
    # pivot below carries them into BENCH_dataflow.json for the gate
    micro_rows += traffic.run_all()
    rows += micro_rows
    rows += roofline.run_all()

    bench = _dataflow_json(micro_rows)
    path = pathlib.Path(__file__).resolve().parent.parent / \
        "BENCH_dataflow.json"
    path.write_text(json.dumps(bench, indent=2, sort_keys=True) + "\n")
    print(f"\nwrote {path}")

    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
