"""Poisson-arrival traffic benchmark for the continuous-batching
serving engine (`repro.serve.gan_engine.GanEngine`).

Producers submit single-sample requests with exponential inter-arrival
times (a Poisson process) at two offered loads calibrated against the
engine's measured capacity:

* **low** — ~0.25x capacity: the engine keeps up, so throughput tracks
  the offered rate and latency is dominated by batch-formation +
  compute (the unloaded service time);
* **high** — ~2x capacity: arrivals outpace compute, requests queue,
  coalescing packs full buckets, and throughput saturates at the
  engine's capacity (the number that matters).

Capacity is measured in the same run (a timed max-bucket batch on the
engine's own executable), so the offered rates adapt to the machine —
the *shape* of the experiment is stable across runner classes even
though the absolute rows are not.

Emitted rows (``micro/<model>/traffic_*``; the ``BENCH_dataflow.json``
pivot in ``benchmarks/run.py`` picks them up):

* ``traffic_capacity_sps`` — calibrated samples/sec (informational);
* ``traffic_{low,high}_offered_rps`` — the Poisson rate actually
  offered (informational; it is derived from capacity);
* ``traffic_{low,high}_throughput_sps`` — served samples / wall-clock
  from first submit to last response.  Gated (higher is better, wide
  threshold — see ``check_regression.GATED_METRICS``);
* ``traffic_{low,high}_p50_us`` / ``_p99_us`` — exact per-request
  submit→response latency percentiles over the run's futures (not
  histogram-approximated).  Gated (lower is better, wide threshold:
  tail latency on a shared CI runner is noisy by nature).

Runnable directly::

    PYTHONPATH=src python benchmarks/traffic.py --models dcgan \
        --requests 30 --buckets 1 2 4

See ``docs/serving.md`` for how to read these rows.
"""

from __future__ import annotations

import argparse
import random
import time

import jax
import numpy as np

DEFAULT_BUCKETS = (1, 2, 4)
DEFAULT_REQUESTS = 30


def _percentile(values, p: float) -> float:
    """Exact linear-interpolation percentile (numpy convention) — the
    run holds every individual latency, so no histogram approximation
    is needed."""
    return float(np.percentile(np.asarray(values, dtype=np.float64), p))


def _calibrate(engine, repeats: int = 4):
    """(low_rps, high_rps, capacity_sps) for single-sample requests,
    measured through the engine's own serving path (scheduler, RNG
    advance, dispatch, device→host copy — the real per-request cost,
    which eager RNG + scheduling overhead can dominate on small
    models, so timing the bucket executable alone would overestimate
    capacity severalfold).

    The sequential rate times back-to-back ``generate(1)`` calls (the
    no-queue regime: each request rides the smallest bucket).  The
    coalesced capacity drains a burst of 3x the largest bucket in one
    go (the backlog regime: full buckets).  "low" offers a quarter of
    the sequential rate so the engine provably keeps up; "high" offers
    twice the coalesced capacity so it provably cannot."""
    engine.generate(1)                      # steady-state, not first-call
    t0 = time.perf_counter()
    for _ in range(repeats):
        engine.generate(1)
    t_seq = (time.perf_counter() - t0) / repeats
    burst = 3 * engine.buckets[-1]
    t0 = time.perf_counter()
    futs = [engine.submit(1) for _ in range(burst)]
    for f in futs:
        f.result(timeout=120)
    capacity = burst / (time.perf_counter() - t0)
    low = 0.25 / t_seq if t_seq > 0 else float("inf")
    return low, 2.0 * capacity, capacity


def _drive(engine, rate_rps: float, n_requests: int, seed: int):
    """Offer ``n_requests`` single-sample requests at Poisson rate
    ``rate_rps``; returns (throughput_sps, sorted latencies_us)."""
    rng = random.Random(seed)
    futures = []
    t0 = time.perf_counter()
    for _ in range(n_requests):
        futures.append(engine.submit(1))
        time.sleep(rng.expovariate(rate_rps))
    for f in futures:
        f.result(timeout=120)
    elapsed = time.perf_counter() - t0
    lats = sorted(f.latency_us for f in futures)
    return (n_requests / elapsed if elapsed > 0 else float("inf")), lats


def run_traffic(models=("dcgan",), channel_scale=0.25,
                buckets=DEFAULT_BUCKETS, n_requests=DEFAULT_REQUESTS,
                seed=0):
    from repro.models.gan import GanConfig, init_gan
    from repro.serve.gan_engine import GanEngine

    rows = []
    print(f"\n== traffic: Poisson arrivals through GanEngine "
          f"(buckets={list(buckets)}, channels×{channel_scale}, "
          f"{n_requests} requests/rate) ==")
    for name in models:
        cfg = GanConfig(name=name, channel_scale=channel_scale)
        g_params, _ = init_gan(cfg, jax.random.PRNGKey(0))
        scenarios = None
        for i, label in enumerate(("low", "high")):
            # a fresh engine per rate: each scenario starts from an
            # empty queue, an empty remainder buffer, and a cold
            # latency record (the bucket set recompiles, which is the
            # engine's real startup cost)
            with GanEngine(cfg, g_params, buckets=buckets,
                           seed=seed) as eng:
                if scenarios is None:
                    low, high, capacity = _calibrate(eng)
                    scenarios = (low, high)
                    rows.append((f"micro/{name}/traffic_capacity_sps",
                                 capacity, "calibrated, informational"))
                rate = scenarios[i]
                throughput, lats = _drive(eng, rate, n_requests, seed)
                assert eng.samples_discarded == 0
            p50, p99 = _percentile(lats, 50), _percentile(lats, 99)
            rows.append((f"micro/{name}/traffic_{label}_offered_rps",
                         rate, "calibrated offer, informational"))
            rows.append((f"micro/{name}/traffic_{label}_throughput_sps",
                         throughput, "served/wall-clock, gated wide"))
            rows.append((f"micro/{name}/traffic_{label}_p50_us", p50,
                         "exact percentile, gated wide"))
            rows.append((f"micro/{name}/traffic_{label}_p99_us", p99,
                         "exact percentile, gated wide"))
            print(f"  {name:8s} {label:4s} offered={rate:8.1f}rps  "
                  f"served={throughput:8.1f}sps  p50={p50/1e3:7.2f}ms  "
                  f"p99={p99/1e3:7.2f}ms")
    return rows


def run_all(models=("dcgan",), channel_scale=0.25,
            buckets=DEFAULT_BUCKETS, n_requests=DEFAULT_REQUESTS,
            seed=0):
    return run_traffic(models, channel_scale, buckets, n_requests, seed)


def main(argv=None):
    from repro.configs.gans import GAN_MODELS

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--models", nargs="+", default=["dcgan"],
                    choices=sorted(GAN_MODELS))
    ap.add_argument("--buckets", nargs="+", type=int,
                    default=list(DEFAULT_BUCKETS))
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS,
                    help="requests per offered-load scenario")
    ap.add_argument("--channel-scale", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run_all(models=tuple(args.models),
                   channel_scale=args.channel_scale,
                   buckets=tuple(args.buckets),
                   n_requests=args.requests, seed=args.seed)


if __name__ == "__main__":
    import pathlib
    import sys
    sys.path.insert(0,
                    str(pathlib.Path(__file__).resolve().parent.parent))
    main()
