"""Reproductions of the paper's figures (analytical model + ISA machine).

One function per table/figure; each returns a list of CSV rows
``(name, value, derived)`` and prints a readable table.
"""

from __future__ import annotations

import numpy as np

from repro.configs.gans import GAN_MODELS
from repro.core.analytical import analyze_layer, analyze_model

PAPER_FIG8 = {  # (speedup, energy) headline anchors from the paper text
    "3dgan": (6.1, None), "magan": (1.3, None),
}
PAPER_MEANS = {"speedup": 3.6, "energy": 3.1}


def _reports():
    return {n: analyze_model(n, g, d) for n, (g, d) in GAN_MODELS.items()}


def fig1_inconsequential():
    """Fig. 1: fraction of inconsequential MACs in tconv layers."""
    rows = []
    print("\n== Fig.1: inconsequential MAC fraction (tconv layers) ==")
    for name, (g, _) in GAN_MODELS.items():
        reps = [analyze_layer(l) for l in g if l.transposed]
        t = sum(r.total_macs for r in reps)
        c = sum(r.consequential_macs for r in reps)
        frac = 1 - c / t
        rows.append((f"fig1/{name}", frac, "fraction_inconsequential"))
        print(f"  {name:10s} {frac:6.3f}")
    mean = np.mean([r[1] for r in rows])
    rows.append(("fig1/mean", mean, "paper: >0.60"))
    print(f"  {'mean':10s} {mean:6.3f}  (paper: >0.60)")
    return rows


def fig8_speedup_energy():
    """Fig. 8: speedup and energy reduction vs EYERISS."""
    rows = []
    reports = _reports()
    print("\n== Fig.8: generative-model speedup / energy vs EYERISS ==")
    sp, en = [], []
    for name, r in reports.items():
        s, e = r.gen_speedup, r.gen_energy_reduction
        sp.append(s)
        en.append(e)
        anchor = PAPER_FIG8.get(name, (None, None))[0]
        rows.append((f"fig8/speedup/{name}", s,
                     f"paper≈{anchor}" if anchor else ""))
        rows.append((f"fig8/energy/{name}", e, ""))
        print(f"  {name:10s} speedup={s:5.2f}x  energy={e:5.2f}x"
              + (f"   (paper {anchor}x)" if anchor else ""))
    rows.append(("fig8/speedup/mean", float(np.mean(sp)), "paper 3.6"))
    rows.append(("fig8/energy/mean", float(np.mean(en)), "paper 3.1"))
    print(f"  {'mean':10s} speedup={np.mean(sp):5.2f}x  "
          f"energy={np.mean(en):5.2f}x   (paper 3.6x / 3.1x)")
    return rows


def fig9_breakdown():
    """Fig. 9: runtime split generative vs discriminative, EYERISS→GANAX."""
    rows = []
    print("\n== Fig.9: runtime split (normalized to EYERISS total) ==")
    for name, r in _reports().items():
        b = r.runtime_split("baseline")
        g = r.runtime_split("ganax")
        tot = b["generative"] + b["discriminative"]
        for which, d in (("eyeriss", b), ("ganax", g)):
            gen = d["generative"] / tot
            dis = d["discriminative"] / tot
            rows.append((f"fig9/{name}/{which}/generative", gen, ""))
            rows.append((f"fig9/{name}/{which}/discriminative", dis, ""))
        print(f"  {name:10s} eyeriss G/D={b['generative']/tot:5.2f}/"
              f"{b['discriminative']/tot:5.2f}  ganax G/D="
              f"{g['generative']/tot:5.2f}/{g['discriminative']/tot:5.2f}")
    return rows


def fig10_energy_units():
    """Fig. 10: energy by microarchitectural unit (normalized)."""
    rows = []
    print("\n== Fig.10: energy by unit (GANAX / EYERISS) ==")
    for name, r in _reports().items():
        eb = r.energy_breakdown("baseline")
        eg = r.energy_breakdown("ganax")
        tot = sum(eb.values())
        parts = " ".join(
            f"{k}={eg[k]/tot:4.2f}/{eb[k]/tot:4.2f}" for k in sorted(eb))
        for k in eb:
            rows.append((f"fig10/{name}/{k}", eg[k] / tot,
                         f"baseline={eb[k]/tot:.3f}"))
        print(f"  {name:10s} {parts}")
    return rows


def fig11_utilization():
    """Fig. 11: PE utilization — analytical + measured on the ISA machine."""
    rows = []
    print("\n== Fig.11: PE utilization ==")
    for name, r in _reports().items():
        ub, ug = r.utilization("baseline"), r.utilization("ganax")
        rows.append((f"fig11/{name}/eyeriss", ub, ""))
        rows.append((f"fig11/{name}/ganax", ug, "paper ≈0.9"))
        print(f"  {name:10s} eyeriss={ub:5.2f}  ganax={ug:5.2f}")
    # ISA-machine measurement on a small representative layer
    from repro.core.scheduler import make_schedule
    from repro.core.uop import run_tconv_on_machine
    rng = np.random.default_rng(0)
    sched = make_schedule((16, 16), (4, 4), (2, 2), (1, 1))
    _, st = run_tconv_on_machine(rng.normal(size=(16, 16)),
                                 rng.normal(size=(4, 4)), sched,
                                 n_pvs=4, pes_per_pv=4)
    rows.append(("fig11/machine_16x16_k4s2", st["utilization"],
                 "ISA-machine measured"))
    print(f"  {'machine':10s} measured={st['utilization']:5.2f} "
          f"(16×16 k4 s2 layer, 4×4 array)")
    return rows


def run_all():
    rows = []
    for fn in (fig1_inconsequential, fig8_speedup_energy, fig9_breakdown,
               fig10_energy_units, fig11_utilization):
        rows.extend(fn())
    return rows


if __name__ == "__main__":
    run_all()
