"""CI bench regression gate: fail on per-model slowdown vs the baseline.

Compares fresh ``BENCH_dataflow.json`` / ``BENCH_tune.json`` artifacts
against the checked-in ``BENCH_baseline.json`` snapshot and exits
non-zero when any gated per-model metric regressed by more than the
threshold (default 25%):

* ``dataflow.<model>.polyphase_us`` — the GANAX dataflow wall-clock per
  Table-I model (the zero-elimination trajectory, 2-D and volumetric);
* ``dataflow.<model>.wallclock_speedup`` — zero-insert/polyphase ratio,
  higher is better.  Machine-relative (both sides measured in the same
  run), so it stays meaningful even when the runner class changes;
* ``dataflow.<model>.fused_us`` — the fused-epilogue generator-layer
  wall-clock (bias+activation inside the unified op), gated against
  its own baseline like the other wall-clock rows (the informational
  ``unfused_us`` / ``fused_speedup`` columns track the same-run
  fused-vs-unfused ratio but do not gate);
* ``dataflow.<model>.program_us`` — the ahead-of-time compiled
  ``repro.program`` generator executable (the supported entry point;
  the informational ``generator_apply_us`` / ``program_speedup``
  columns track the same-run legacy-vs-program ratio but do not gate);
* ``dataflow.<model>.generator_bf16_us`` — the same executable at
  bf16 storage precision (``repro.quant``); ``generator_int8_us`` and
  the analytic ``hbm_bytes_{f32,bf16,int8}`` rows are informational;
* ``tune.<model>.generator_tuned_us`` — the tuned end-to-end generator.

Faster-than-baseline results always pass (speedups are the point); a
model present in the baseline but missing from the fresh artifacts is a
coverage regression and fails; new models not in the baseline are
reported but don't gate.

Absolute wall-clock baselines are machine-class-sensitive: after a
runner change (or when the checked-in baseline predates one), refresh
it from a green run's artifacts with ``--update`` — the dimensionless
``wallclock_speedup`` rows keep gating meaningfully in the meantime.

Override: CI sets ``BENCH_GATE_OVERRIDE=1`` when the PR carries the
``bench-regression-override`` label — regressions are then reported but
the job stays green (for noisy-runner false positives or accepted
trade-offs; refresh the baseline with ``--update`` in the same PR).

Usage::

    python benchmarks/check_regression.py \
        --baseline BENCH_baseline.json \
        --dataflow BENCH_dataflow.json --tune BENCH_tune.json
    python benchmarks/check_regression.py --update   # refresh baseline
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

# (section, per-model metric, direction) the gate tracks: "lower" =
# wall-clock (bigger is a regression), "higher" = ratio (smaller is),
# "cap:<N>" = absolute ceiling (fresh value above N fails, baseline
# value irrelevant — for metrics like a percentage overhead where
# gating relative to a near-zero baseline would be meaningless).
# "lower*<M>" / "higher*<M>" widen the run's threshold by M for that
# row — for metrics that must stay gated but are intrinsically noisy
# (queueing-tail latency on a shared runner), where the standard
# threshold would flake without measuring anything real.
GATED_METRICS = (
    ("dataflow", "polyphase_us", "lower"),
    ("dataflow", "wallclock_speedup", "higher"),
    ("dataflow", "fused_us", "lower"),
    ("dataflow", "program_us", "lower"),
    # benchmarks/microbench.py bench_precision: the bf16-storage
    # generator executable (repro.quant) — the low-precision path must
    # not regress; the generator_int8_us and hbm_bytes_* rows it ships
    # with stay informational (int8 timing duplicates the bf16
    # executable with dequantized weights, and the byte rows are
    # analytic constants).
    ("dataflow", "generator_bf16_us", "lower"),
    ("dataflow", "obs_overhead_pct", "cap:2.0"),
    ("dataflow", "traffic_low_throughput_sps", "higher*2"),
    ("dataflow", "traffic_high_throughput_sps", "higher*2"),
    ("dataflow", "traffic_low_p50_us", "lower*2"),
    ("dataflow", "traffic_low_p99_us", "lower*2"),
    ("dataflow", "traffic_high_p50_us", "lower*2"),
    ("dataflow", "traffic_high_p99_us", "lower*2"),
    # benchmarks/scaling.py: single-device row of the DP-scaling sweep
    # (widened: measured in a forced-8-device process, noisier than
    # program_us); the 2x/4x/dp_speedup rows stay informational — on a
    # CPU runner the forced devices share cores, so they measure
    # partitioning overhead, not parallel speedup.
    ("dataflow", "dp_scaling_1x_us", "lower*2"),
    ("tune", "generator_tuned_us", "lower"),
)
DEFAULT_THRESHOLD = 0.25


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _models(doc: dict) -> dict:
    return {k: v for k, v in doc.items()
            if k != "_meta" and isinstance(v, dict)}


def extract(dataflow: dict, tune: dict) -> dict:
    """The gated (section → model → metric value) snapshot of two fresh
    artifact files."""
    fresh = {"dataflow": {}, "tune": {}}
    sources = {"dataflow": _models(dataflow), "tune": _models(tune)}
    for section, metric, _ in GATED_METRICS:
        for model, row in sources[section].items():
            value = row.get(metric)
            # >= 0: cap metrics (e.g. a clamped overhead pct) are
            # legitimately zero; ratio/wall-clock rows never are
            if isinstance(value, (int, float)) and value >= 0 and \
                    (value > 0 or metric.endswith("_pct")):
                fresh[section].setdefault(model, {})[metric] = value
    return fresh


def compare(baseline: dict, fresh: dict, threshold: float
            ) -> tuple[list[str], list[str]]:
    """(failures, report_lines) of fresh vs baseline."""
    failures: list[str] = []
    lines = ["| metric | baseline | fresh | regression | gate |",
             "|---|---|---|---|---|"]
    for section, metric, direction in GATED_METRICS:
        base_models = baseline.get(section, {})
        fresh_models = fresh.get(section, {})
        for model in sorted(set(base_models) | set(fresh_models)):
            name = f"{section}/{model}/{metric}"
            base = base_models.get(model, {}).get(metric)
            new = fresh_models.get(model, {}).get(metric)
            if base is None and new is None:
                continue    # metric not tracked for this model
            if direction.startswith("cap:"):
                # absolute ceiling: the fresh value alone decides
                cap = float(direction.split(":", 1)[1])
                if new is None:
                    failures.append(f"{name}: present in baseline but "
                                    f"missing from the fresh artifacts")
                    lines.append(f"| {name} | cap {cap:,.2f} | - | - | "
                                 f"MISSING |")
                    continue
                gate = "FAIL" if new > cap else "ok"
                if new > cap:
                    failures.append(f"{name}: {new:,.2f} exceeds the "
                                    f"absolute cap {cap:,.2f}")
                lines.append(f"| {name} | cap {cap:,.2f} | {new:,.2f} | "
                             f"- | {gate} |")
                continue
            if base is None:
                lines.append(f"| {name} | - | {new:,.2f} | new | - |")
                continue
            if new is None:
                failures.append(f"{name}: present in baseline but "
                                f"missing from the fresh artifacts")
                lines.append(f"| {name} | {base:,.2f} | - | - | MISSING |")
                continue
            # "lower*2" → lower-is-better at twice the run threshold
            sense, _, mult = direction.partition("*")
            limit = threshold * (float(mult) if mult else 1.0)
            # positive = got worse, whatever the metric's direction
            regress = (new / base if sense == "lower"
                       else base / new) - 1.0
            gate = "FAIL" if regress > limit else "ok"
            if regress > limit:
                failures.append(
                    f"{name}: {base:,.2f} -> {new:,.2f} "
                    f"({regress:+.1%} worse > +{limit:.0%} threshold)")
            lines.append(f"| {name} | {base:,.2f} | {new:,.2f} | "
                         f"{regress:+.1%} | {gate} |")
    return failures, lines


def main(argv=None) -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=str(root / "BENCH_baseline.json"))
    ap.add_argument("--dataflow", default=str(root / "BENCH_dataflow.json"))
    ap.add_argument("--tune", default=str(root / "BENCH_tune.json"))
    ap.add_argument("--threshold", type=float, default=None,
                    help="per-model slowdown fraction that fails the gate "
                         f"(default: baseline file's, else "
                         f"{DEFAULT_THRESHOLD})")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh artifacts "
                         "instead of gating")
    args = ap.parse_args(argv)

    fresh = extract(_load(args.dataflow), _load(args.tune))
    if args.update:
        doc = {"threshold": args.threshold or DEFAULT_THRESHOLD, **fresh}
        pathlib.Path(args.baseline).write_text(
            json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"wrote {args.baseline}")
        return 0

    baseline = _load(args.baseline)
    threshold = args.threshold if args.threshold is not None else \
        float(baseline.get("threshold", DEFAULT_THRESHOLD))
    failures, lines = compare(baseline, fresh, threshold)

    print(f"## Bench regression gate (threshold +{threshold:.0%})\n")
    print("\n".join(lines))
    override = os.environ.get("BENCH_GATE_OVERRIDE", "") not in ("", "0")
    if failures:
        print(f"\n{len(failures)} regression(s):")
        for f in failures:
            print(f"  - {f}")
        if override:
            print("\nBENCH_GATE_OVERRIDE set "
                  "(bench-regression-override label): not failing the "
                  "job; refresh BENCH_baseline.json with --update if "
                  "this slowdown is accepted.")
            return 0
        print("\nSlower than baseline. If this is expected (accepted "
              "trade-off or noisy runner), apply the "
              "`bench-regression-override` label and/or refresh the "
              "baseline: python benchmarks/check_regression.py --update")
        return 1
    print("\nNo regressions.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
