"""Fault-tolerance demo: checkpoint → injected crash → restore → identical
final state; then an *elastic* restore of the same checkpoint onto a
different mesh shape (run in a subprocess with 8 fake devices).

::

    PYTHONPATH=src python examples/elastic_restart.py
"""

import dataclasses
import shutil
import tempfile

import jax
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM, make_batch_fn
from repro.models import transformer as tr
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import AdamWConfig
from repro.train.train_state import init_train_state, make_train_step

CFG = dataclasses.replace(
    get_config("gemma-7b"), n_layers=2, d_model=64, d_ff=128, vocab=256,
    n_heads=2, n_kv_heads=2, head_dim=32, tie_embeddings=False)


def run(tmp, inject):
    step = jax.jit(make_train_step(CFG, AdamWConfig(peak_lr=1e-3,
                                                    warmup_steps=2),
                                   tr.RunFlags(remat=False)))
    src = SyntheticLM(CFG, batch=4, seq_len=32, seed=0)
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    loop = TrainLoop(
        LoopConfig(total_steps=16, ckpt_dir=tmp, ckpt_every=4,
                   async_ckpt=False, log_every=4),
        step, make_batch_fn(src), state, failure_injector=inject)
    return loop.run(), loop


def main():
    tmp = tempfile.mkdtemp(prefix="elastic_")
    fired = []

    def inject(s):
        if s == 9 and not fired:
            fired.append(True)
            print(f"[elastic] >>> injecting node failure at step {s} <<<")
            return True
        return False

    print("[elastic] run A: crash at step 9, restore from checkpoint 8")
    state_a, loop_a = run(tmp, inject)
    shutil.rmtree(tmp)
    print(f"[elastic] run A restarts={loop_a.restarts}")

    print("[elastic] run B: uninterrupted control")
    state_b, _ = run(tmp, None)

    diffs = [float(np.max(np.abs(np.asarray(a, np.float32)
                                 - np.asarray(b, np.float32))))
             for a, b in zip(jax.tree.leaves(state_a["params"]),
                             jax.tree.leaves(state_b["params"]))]
    print(f"[elastic] max param divergence crash-vs-control: {max(diffs):.2e}")
    assert max(diffs) < 1e-5, "restart must replay deterministically"

    print("[elastic] elastic reshard (subprocess, 8 fake devices): "
          "save on (4,2), restore on (2,2) and (8,) …")
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "import jax, jax.numpy as jnp, numpy as np, tempfile;"
        "from jax.sharding import PartitionSpec as P, NamedSharding;"
        "from repro.train import checkpoint as ckpt;"
        "d=tempfile.mkdtemp();"
        "m=jax.make_mesh((4,2),('data','model'));"
        "x=jnp.arange(64.).reshape(8,8);"
        "ckpt.save({'w':jax.device_put(x,NamedSharding(m,P('data','model')))},d,1);"
        "m2=jax.make_mesh((2,2),('data','model'));"
        "o=ckpt.restore({'w':jnp.zeros((8,8))},d,1,"
        "{'w':NamedSharding(m2,P('model','data'))});"
        "assert (np.asarray(o['w'])==np.asarray(x)).all();"
        "print('[elastic] reshard OK')")
    out = subprocess.run([sys.executable, "-c", code], cwd=root,
                         env=dict(os.environ,
                                  PYTHONPATH=os.path.join(root, "src")),
                         capture_output=True, text=True)
    print(out.stdout.strip() or out.stderr[-500:])
    print("[elastic] done")


if __name__ == "__main__":
    main()
