"""Batched serving example (deliverable (b)): continuous batching over a
slot-based decode engine with a shared static cache.

::

    PYTHONPATH=src python examples/serve_llm.py --arch minicpm3-4b
"""

import argparse

from repro.launch import serve as serve_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-32b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    serve_cli.main([
        "--arch", args.arch, "--preset", "tiny",
        "--requests", str(args.requests), "--max-new", str(args.max_new),
    ])


if __name__ == "__main__":
    main()
