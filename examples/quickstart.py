"""Quickstart: train a tiny DCGAN with the GANAX dataflow on CPU.

This is the **Program API** flow — the supported entry point.  The
config → policy → epilogue → plan walk runs exactly twice
(``make_gan_train_step`` builds the generator and discriminator
programs ahead of the first trace); training replays the frozen
programs under the fault-tolerant ``TrainLoop``, and serving
demonstrates the full build → export → load → serve loop: the trained
generator's program spec is written to JSON, re-loaded as if on a
fresh serving box, and handed to ``GanServer``::

    PYTHONPATH=src python examples/quickstart.py --steps 30

Pick the execution path with ``--backend`` (``polyphase`` by default;
``pallas-interpret`` exercises the kernel semantics, ``zero-insert`` is
the conventional-accelerator baseline, ``auto`` consults the repro.tune
planner — point ``REPRO_TUNE_PLANS`` at a plan file from
``python -m repro.tune`` for measured plans).
"""

import argparse
import pathlib
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.models.gan import GanConfig, init_gan
from repro.program import Program, ProgramSpec
from repro.serve.gan import GanServer
from repro.train.loop import LoopConfig, TrainLoop, make_gan_train_step


def synthetic_reals(key, batch):
    """'Real' data: smooth blobs (enough for a quickstart objective)."""
    k1, k2 = jax.random.split(key)
    xy = jnp.linspace(-1, 1, 64)
    gx, gy = jnp.meshgrid(xy, xy)
    centers = jax.random.uniform(k1, (batch, 2), minval=-0.5, maxval=0.5)
    r = jax.random.uniform(k2, (batch, 1), minval=0.1, maxval=0.4)
    d2 = ((gx[None] - centers[:, :1, None]) ** 2
          + (gy[None] - centers[:, 1:, None]) ** 2)
    img = jnp.exp(-d2 / (2 * r[..., None] ** 2))
    return jnp.tanh(img)[..., None] * jnp.ones((1, 1, 1, 3))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=4e-3)
    ap.add_argument("--channel-scale", type=float, default=0.0625)
    ap.add_argument("--backend", default="polyphase",
                    help="dataflow backend (polyphase | zero-insert | "
                         "pallas | pallas-interpret | auto)")
    args = ap.parse_args()

    cfg = GanConfig(name="dcgan", channel_scale=args.channel_scale,
                    backend=args.backend)
    g_params, d_params = init_gan(cfg, jax.random.PRNGKey(0))

    # One ahead-of-time resolution for the whole run: both networks'
    # programs are frozen here, before anything traces.
    train_step, (g_prog, d_prog) = make_gan_train_step(
        cfg, args.batch, g_lr=args.lr * 5, measure=True)
    print(g_prog.describe())
    print(d_prog.describe())

    def batch_fn(step):
        # pure function of step → exact replay after any restart
        kz, kr = jax.random.split(jax.random.PRNGKey(step))
        return {"z": jax.random.normal(kz, (args.batch, cfg.z_dim)),
                "real": synthetic_reals(kr, args.batch)}

    t0 = time.time()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoop(
            LoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                       ckpt_every=max(10, args.steps // 2), log_every=5),
            train_step, batch_fn, (g_params, d_params))
        g_params, d_params = loop.run()
    print(f"done: {args.steps} adversarial steps through the "
          f"{args.backend} dataflow in {time.time()-t0:.1f}s")

    # Build → export → load → serve: ship the tuned program as data.
    with tempfile.TemporaryDirectory() as d:
        path = pathlib.Path(d) / "generator-program.json"
        g_prog.save(path)
        spec = ProgramSpec.load(path)          # a fresh serving process
        server = GanServer(cfg, g_params, batch_size=args.batch,
                           program=Program(spec, differentiable=False))
        imgs = server.generate(3)
    print(f"served {imgs.shape[0]} samples {imgs.shape[1:]} from the "
          f"exported program in {server.batches_served} batch(es) "
          f"({server.samples_buffered} buffered for the next call)")


if __name__ == "__main__":
    main()
