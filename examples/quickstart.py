"""Quickstart: train a tiny DCGAN with the GANAX dataflow on CPU.

Every (transposed) convolution runs through the unified dataflow dispatch
(`core.dataflow`); pick the execution path with ``--backend``
(``polyphase`` by default; ``pallas-interpret`` exercises the kernel
semantics, ``zero-insert`` is the conventional-accelerator baseline).
Training runs under the fault-tolerant ``TrainLoop`` and finishes with a
batch of served samples from ``serve.gan.GanServer``::

    PYTHONPATH=src python examples/quickstart.py --steps 30
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.models.gan import GanConfig, gan_losses, init_gan
from repro.serve.gan import GanServer
from repro.train.loop import LoopConfig, TrainLoop


def synthetic_reals(key, batch):
    """'Real' data: smooth blobs (enough for a quickstart objective)."""
    k1, k2 = jax.random.split(key)
    xy = jnp.linspace(-1, 1, 64)
    gx, gy = jnp.meshgrid(xy, xy)
    centers = jax.random.uniform(k1, (batch, 2), minval=-0.5, maxval=0.5)
    r = jax.random.uniform(k2, (batch, 1), minval=0.1, maxval=0.4)
    d2 = ((gx[None] - centers[:, :1, None]) ** 2
          + (gy[None] - centers[:, 1:, None]) ** 2)
    img = jnp.exp(-d2 / (2 * r[..., None] ** 2))
    return jnp.tanh(img)[..., None] * jnp.ones((1, 1, 1, 3))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=4e-3)
    ap.add_argument("--channel-scale", type=float, default=0.0625)
    ap.add_argument("--backend", default="polyphase",
                    help="dataflow backend (polyphase | zero-insert | "
                         "pallas | pallas-interpret | auto — 'auto' "
                         "consults the repro.tune planner; point "
                         "REPRO_TUNE_PLANS at a plan file from "
                         "`python -m repro.tune` for measured plans)")
    args = ap.parse_args()

    cfg = GanConfig(name="dcgan", channel_scale=args.channel_scale,
                    backend=args.backend)
    g_params, d_params = init_gan(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def train_step(state, batch):
        g_params, d_params = state
        z, real = batch["z"], batch["real"]

        def d_loss(d):
            _, dl, _ = gan_losses(g_params, d, z, real, cfg)
            return dl

        def g_loss(g):
            gl, _, _ = gan_losses(g, d_params, z, real, cfg)
            return gl

        dl, d_grads = jax.value_and_grad(d_loss)(d_params)
        d_new = jax.tree.map(lambda p, gr: p - args.lr * 5 * gr,
                             d_params, d_grads)
        gl, g_grads = jax.value_and_grad(g_loss)(g_params)
        g_new = jax.tree.map(lambda p, gr: p - args.lr * 5 * gr,
                             g_params, g_grads)
        return (g_new, d_new), {"g_loss": gl, "d_loss": dl,
                                "loss": gl + dl}

    def batch_fn(step):
        # pure function of step → exact replay after any restart
        kz, kr = jax.random.split(jax.random.PRNGKey(step))
        return {"z": jax.random.normal(kz, (args.batch, cfg.z_dim)),
                "real": synthetic_reals(kr, args.batch)}

    t0 = time.time()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoop(
            LoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                       ckpt_every=max(10, args.steps // 2), log_every=5),
            train_step, batch_fn, (g_params, d_params))
        g_params, d_params = loop.run()
    print(f"done: {args.steps} adversarial steps through the "
          f"{args.backend} dataflow in {time.time()-t0:.1f}s")

    server = GanServer(cfg, g_params, batch_size=args.batch)
    imgs = server.generate(3)
    print(f"served {imgs.shape[0]} samples {imgs.shape[1:]} "
          f"in {server.batches_served} batch(es)")


if __name__ == "__main__":
    main()
