"""Quickstart: train a tiny DCGAN with the GANAX dataflow on CPU.

Every transposed convolution in the generator runs through the paper's
polyphase (zero-eliminated) dataflow.  Runs in ~a minute::

    PYTHONPATH=src python examples/quickstart.py --steps 30
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gan import GanConfig, gan_losses, init_gan


def synthetic_reals(key, batch):
    """'Real' data: smooth blobs (enough for a quickstart objective)."""
    k1, k2 = jax.random.split(key)
    xy = jnp.linspace(-1, 1, 64)
    gx, gy = jnp.meshgrid(xy, xy)
    centers = jax.random.uniform(k1, (batch, 2), minval=-0.5, maxval=0.5)
    r = jax.random.uniform(k2, (batch, 1), minval=0.1, maxval=0.4)
    d2 = ((gx[None] - centers[:, :1, None]) ** 2
          + (gy[None] - centers[:, 1:, None]) ** 2)
    img = jnp.exp(-d2 / (2 * r[..., None] ** 2))
    return jnp.tanh(img)[..., None] * jnp.ones((1, 1, 1, 3))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=4e-3)
    ap.add_argument("--channel-scale", type=float, default=0.0625)
    args = ap.parse_args()

    cfg = GanConfig(name="dcgan", channel_scale=args.channel_scale,
                    dataflow="ganax")
    g_params, d_params = init_gan(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def train_step(g_params, d_params, z, real):
        def d_loss(d):
            _, dl, _ = gan_losses(g_params, d, z, real, cfg)
            return dl

        def g_loss(g):
            gl, _, _ = gan_losses(g, d_params, z, real, cfg)
            return gl

        dl, d_grads = jax.value_and_grad(d_loss)(d_params)
        d_new = jax.tree.map(lambda p, gr: p - args.lr * 5 * gr,
                             d_params, d_grads)
        gl, g_grads = jax.value_and_grad(g_loss)(g_params)
        g_new = jax.tree.map(lambda p, gr: p - args.lr * 5 * gr,
                             g_params, g_grads)
        return g_new, d_new, gl, dl

    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for step in range(args.steps):
        key, kz, kr = jax.random.split(key, 3)
        z = jax.random.normal(kz, (args.batch, cfg.z_dim))
        real = synthetic_reals(kr, args.batch)
        g_params, d_params, gl, dl = train_step(g_params, d_params, z,
                                                real)
        if step % 5 == 0:
            print(f"step {step:3d}  g_loss={float(gl):6.3f} "
                  f"d_loss={float(dl):6.3f}  ({time.time()-t0:5.1f}s)")
    print(f"done: {args.steps} adversarial steps through the GANAX "
          f"polyphase dataflow in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
