"""End-to-end LM training driver (deliverable (b)): train a ~100M-param
model for a few hundred steps with the full production stack — synthetic
deterministic data, AdamW + cosine schedule, grad accumulation, async
checkpointing, fault-tolerant loop.

CPU demo (a ~5M model, a couple of minutes)::

    PYTHONPATH=src python examples/train_lm.py --steps 40

The real thing (same code path; ~100M params, a few hundred steps)::

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300 \
        --batch 32 --seq 512
"""

import argparse
import sys

sys.argv0 = sys.argv[0]

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    train_cli.main([
        "--arch", args.arch, "--preset", args.preset,
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
    ])


if __name__ == "__main__":
    main()
