"""Executable model of the GANAX ISA (paper §III-B and §IV).

A *software* model of the accelerator, faithful at the level the paper's
figures describe:

* :class:`StridedIndexGenerator` — the access μ-engine's reconfigurable
  index generator (Fig. 7b): ``Addr/Offset/Step/End/Repeat`` registers and a
  modulo adder, emitting one address per cycle.
* Access μops (``access.cfg``, ``access.start``) and execute μops (``mac``,
  ``repeat``/``mimd.ld``) per §IV; execute μops carry **no address fields**
  — all operand addresses stream from the generators (decoupled
  access-execute).
* :class:`GanaxMachine` — a PV×PE array interpreter.  Each PV runs its own
  μop stream (MIMD across PVs) while all PEs inside a PV execute the same
  μop on different data (SIMD).  Running the same program in *SIMD-lockstep*
  mode (every global step waits for the slowest PV) models a conventional
  accelerator on the same reorganized dataflow, quantifying the MIMD win.

:func:`compile_tconv_program` performs the paper's static translation of a
2-D transposed-conv layer: output rows grouped by zero-pattern (y-phase,
"output row reorganization"), filter taps regrouped per phase ("filter row
reorganization"), column access as strided generator sweeps over only the
consequential taps (fine-grain zero skipping).  Executing the compiled
program reproduces the JAX reference bit-for-bit (float64) — the end-to-end
ISA-level validation — and yields cycle/utilization statistics (Fig. 11).
"""

from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.scheduler import PhaseSchedule

__all__ = [
    "StridedIndexGenerator",
    "Uop",
    "UopKind",
    "PEProgram",
    "GanaxMachine",
    "compile_tconv_program",
    "run_tconv_on_machine",
]


class StridedIndexGenerator:
    """Fig. 7(b): five config registers + a modulo adder; one address/cycle.

    The generator sweeps ``Addr, Addr+Step, …`` modulo ``End``; each wrap
    decrements ``Repeat``; when ``Repeat`` hits zero the stop signal rises.
    ``Offset`` shifts the emitted range (so the same sweep can be replayed
    over different bases without reprogramming the sweep itself).
    """

    __slots__ = ("addr", "offset", "step", "end", "repeat", "_cur",
                 "running")

    REGS = ("addr", "offset", "step", "end", "repeat")

    def __init__(self) -> None:
        self.addr = 0
        self.offset = 0
        self.step = 0
        self.end = 1 << 30
        self.repeat = 1
        self._cur = 0
        self.running = False

    def configure(self, reg: str, value: int) -> None:  # access.cfg
        if reg not in self.REGS:
            raise ValueError(f"unknown config register {reg!r}")
        setattr(self, reg, int(value))

    def start(self) -> None:  # access.start
        self._cur = self.addr
        self.running = True

    def stop(self) -> None:  # access.stop
        self.running = False

    def emit(self) -> int:
        if not self.running:
            raise RuntimeError("index generator stopped (FIFO empty)")
        out = self.offset + self._cur
        nxt = self._cur + self.step
        if self.step >= 0 and nxt >= self.end:
            nxt -= self.end
            self.repeat -= 1
            if self.repeat <= 0:
                self.running = False
        elif self.step < 0 and nxt < 0:
            nxt += self.end
            self.repeat -= 1
            if self.repeat <= 0:
                self.running = False
        self._cur = nxt
        return out


class UopKind(enum.Enum):
    ACCESS_CFG = "access.cfg"      # %gen, %reg, imm  (per-PE imm table)
    ACCESS_START = "access.start"  # %gen
    MIMD_LD = "mimd.ld"            # load repeat register, imm per PE
    MAC = "mac"                    # repeat-register many MACs, no addresses
    NOP = "nop"


@dataclasses.dataclass(frozen=True)
class Uop:
    """One μop as issued to a PV.  ``imms`` carries the per-PE immediate
    (hardware: SIMD broadcast with per-lane operand from the access engine;
    configuration values differ per PE because each PE owns a different
    output row)."""
    kind: UopKind
    gen: int | None = None
    reg: str | None = None
    imms: tuple[int, ...] | None = None  # one immediate per PE (or None)


# Generator roles per PE
GEN_IN, GEN_W, GEN_OUT = 0, 1, 2


class _PE:
    __slots__ = ("gens", "repeat_reg", "busy_cycles", "macs")

    def __init__(self) -> None:
        self.gens = [StridedIndexGenerator() for _ in range(3)]
        self.repeat_reg = 0
        self.busy_cycles = 0
        self.macs = 0


@dataclasses.dataclass
class PEProgram:
    """A per-PV μop stream (all PEs in the PV execute it in SIMD)."""
    uops: list[Uop]


class GanaxMachine:
    """PV × PE array with decoupled access-execute PEs (Fig. 6/7)."""

    def __init__(self, n_pvs: int = 16, pes_per_pv: int = 16) -> None:
        self.n_pvs = n_pvs
        self.pes_per_pv = pes_per_pv
        self.pes = [[_PE() for _ in range(pes_per_pv)]
                    for _ in range(n_pvs)]
        self.mem: dict[str, np.ndarray] = {}

    def load_memory(self, name: str, arr: np.ndarray) -> None:
        self.mem[name] = np.array(arr, dtype=np.float64).ravel()

    def _exec(self, pv: int, uop: Uop) -> int:
        """Execute one μop across the PV; returns the PV's cycle cost."""
        cost = 0
        for pe_idx in range(self.pes_per_pv):
            pe = self.pes[pv][pe_idx]
            imm = uop.imms[pe_idx] if uop.imms is not None else None
            k = uop.kind
            if k == UopKind.NOP:
                c = 0
            elif k == UopKind.ACCESS_CFG:
                if imm is not None:
                    pe.gens[uop.gen].configure(uop.reg, imm)
                c = 1
            elif k == UopKind.ACCESS_START:
                if imm is None or imm:
                    pe.gens[uop.gen].start()
                c = 1
            elif k == UopKind.MIMD_LD:
                pe.repeat_reg = imm if imm is not None else 0
                c = 1
            elif k == UopKind.MAC:
                reps = pe.repeat_reg
                x, w, o = self.mem["input"], self.mem["weight"], \
                    self.mem["output"]
                for _ in range(reps):
                    ia = pe.gens[GEN_IN].emit()
                    wa = pe.gens[GEN_W].emit()
                    oa = pe.gens[GEN_OUT].emit()
                    o[oa] += x[ia] * w[wa]
                pe.busy_cycles += reps
                pe.macs += reps
                c = reps
            else:
                raise NotImplementedError(k)
            cost = max(cost, c)
        return cost

    def run(self, programs: list[PEProgram], mimd: bool = True) -> dict:
        """Execute one μop stream per PV.

        MIMD-SIMD mode: PVs run independently; time = max PV time.
        SIMD-lockstep mode (``mimd=False``): global stream steps advance in
        lockstep; every step costs the max across PVs (idle PVs wait) —
        the conventional-accelerator behavior the paper contrasts against.
        """
        assert len(programs) == self.n_pvs
        pv_times = [0] * self.n_pvs
        if mimd:
            for pv, prog in enumerate(programs):
                for uop in prog.uops:
                    pv_times[pv] += self._exec(pv, uop)
            cycles = max(pv_times)
        else:
            n_steps = max(len(p.uops) for p in programs)
            cycles = 0
            for i in range(n_steps):
                step_cost = 0
                for pv, prog in enumerate(programs):
                    if i < len(prog.uops):
                        step_cost = max(step_cost,
                                        self._exec(pv, prog.uops[i]))
                cycles += step_cost
            pv_times = [cycles] * self.n_pvs
        busy = sum(pe.busy_cycles for row in self.pes for pe in row)
        total_slots = cycles * self.n_pvs * self.pes_per_pv
        return {
            "cycles": cycles,
            "pv_cycles": pv_times,
            "busy_pe_cycles": busy,
            "utilization": busy / total_slots if total_slots else 0.0,
            "macs": sum(pe.macs for row in self.pes for pe in row),
        }


# ---------------------------------------------------------------------------
# Static translation of a 2-D transposed conv (the paper's compiler).
# ---------------------------------------------------------------------------

def compile_tconv_program(sched: PhaseSchedule, n_pvs: int, pes_per_pv: int,
                          wq_pad: int, wp: int
                          ) -> tuple[list[PEProgram], list]:
    """Compile the layer into one μop stream per PV.

    Output rows are reorganized phase-major (rows with identical zero
    patterns adjacent — Fig. 5a, longest microprogram first) and dealt to
    PE slots in contiguous runs, so a PV serves rows of a single y-phase
    whenever possible (the compulsory adjacency that reclaims filter-row
    reuse across neighboring PEs).  Each PE owns a run of reorganized
    output rows; its program is one ``(cfg×…, start×3, mimd.ld, mac)``
    block per consequential ``(row, ky, x-phase, kx)`` tap triple — program
    length therefore varies with the y-phase mix (MIMD across PVs).

    ``wq_pad``: row pitch of the reorganized output buffer;
    ``wp``: row pitch of the (padded) input buffer.
    Returns (programs, reorg_rows).
    """
    if sched.n_dims != 2:
        raise ValueError("ISA-level model is 2-D")
    y_dims, x_dims = sched.dims
    (py_lo, _), (px_lo, _) = sched.uniform_padding()

    # Reorganized row order: phase groups, longest microprogram first.
    reorg_rows: list[tuple[int, int]] = []
    for pd in sorted(y_dims, key=lambda p: p.n_taps, reverse=True):
        reorg_rows.extend((pd.phase, q) for q in range(pd.out_size))

    n_slots = n_pvs * pes_per_pv
    # Contiguous dealing: slot k owns rows [k*per, ...) — keeps a PV within
    # one phase group when possible.
    per = -(-len(reorg_rows) // n_slots)
    slot_rows: list[list[int]] = [
        list(range(k * per, min((k + 1) * per, len(reorg_rows))))
        for k in range(n_slots)]

    # Column phase layout inside a reorganized output row: x-phases stored
    # contiguously (phase-major), widths xd.out_size, in phase order.
    x_base = {}
    acc = 0
    for xd in x_dims:
        x_base[xd.phase] = acc
        acc += xd.out_size

    programs: list[PEProgram] = []
    for pv in range(n_pvs):
        progs_per_pe = []
        for pe_idx in range(pes_per_pv):
            slot = pv * pes_per_pv + pe_idx
            blocks = []
            for r in slot_rows[slot]:
                blocks.extend(_row_blocks(r, reorg_rows[r], sched, x_dims,
                                          y_dims, x_base, wq_pad, wp,
                                          px_lo, py_lo))
            progs_per_pe.append(blocks)
        n_blocks = max(len(b) for b in progs_per_pe)
        uops: list[Uop] = []
        for bi in range(n_blocks):
            blocks = [b[bi] if bi < len(b) else None for b in progs_per_pe]
            uops.extend(_emit_block(blocks))
        programs.append(PEProgram(uops))
    return programs, reorg_rows


def _row_blocks(r, yq, sched, x_dims, y_dims, x_base, wq_pad, wp,
                px_lo, py_lo):
    """MAC blocks for reorganized output row ``r``."""
    y_phase, qy = yq
    ypd = y_dims[y_phase]
    blocks = []
    for ty, ky in enumerate(ypd.taps):
        in_row = qy + ypd.offset - ty + py_lo
        for xd in x_dims:
            for tx, kx in enumerate(xd.taps):
                blocks.append(dict(
                    in_start=in_row * wp + (xd.offset - tx + px_lo),
                    w_addr=ky * sched.kernel[1] + kx,
                    out_start=r * wq_pad + x_base[xd.phase],
                    n=xd.out_size,
                    in_step=1, out_step=1,
                ))
    return blocks


def _emit_block(blocks) -> list[Uop]:
    """Emit the μop sequence for one MAC block across a PV's PEs.

    Per the paper, execute μops are address-free; the access μops configure
    the three generators, then ``mimd.ld`` sets the repeat register and a
    single ``mac`` μop streams the whole sweep.
    """
    def imm(key, default=0):
        return tuple(b[key] if b is not None else default for b in blocks)

    active = tuple(1 if b is not None else 0 for b in blocks)
    n = imm("n", 0)
    uops = [
        Uop(UopKind.ACCESS_CFG, gen=GEN_IN, reg="addr", imms=imm("in_start")),
        Uop(UopKind.ACCESS_CFG, gen=GEN_IN, reg="step", imms=imm("in_step", 1)),
        Uop(UopKind.ACCESS_CFG, gen=GEN_IN, reg="end",
            imms=tuple(1 << 30 for _ in blocks)),
        Uop(UopKind.ACCESS_CFG, gen=GEN_IN, reg="repeat",
            imms=tuple(1 for _ in blocks)),
        Uop(UopKind.ACCESS_CFG, gen=GEN_W, reg="addr", imms=imm("w_addr")),
        Uop(UopKind.ACCESS_CFG, gen=GEN_W, reg="step",
            imms=tuple(0 for _ in blocks)),
        Uop(UopKind.ACCESS_CFG, gen=GEN_OUT, reg="addr", imms=imm("out_start")),
        Uop(UopKind.ACCESS_CFG, gen=GEN_OUT, reg="step", imms=imm("out_step", 1)),
        Uop(UopKind.ACCESS_CFG, gen=GEN_OUT, reg="end",
            imms=tuple(1 << 30 for _ in blocks)),
        Uop(UopKind.ACCESS_START, gen=GEN_IN, imms=active),
        Uop(UopKind.ACCESS_START, gen=GEN_W, imms=active),
        Uop(UopKind.ACCESS_START, gen=GEN_OUT, imms=active),
        Uop(UopKind.MIMD_LD, imms=n),
        Uop(UopKind.MAC),
    ]
    return uops


def run_tconv_on_machine(x: np.ndarray, w: np.ndarray,
                         sched: PhaseSchedule,
                         n_pvs: int = 4, pes_per_pv: int = 4,
                         mimd: bool = True
                         ) -> tuple[np.ndarray, dict]:
    """Execute a single-channel 2-D tconv end-to-end through the ISA model.

    Every arithmetic contribution flows through the strided index
    generators and address-free ``mac`` μops; the result is then
    de-reorganized (inverse of the output-row/column reorganization) and
    compared against the dense reference by the tests.
    """
    y_dims, x_dims = sched.dims
    (py_lo, py_hi), (px_lo, px_hi) = sched.uniform_padding()
    xp = np.pad(np.asarray(x, np.float64), ((py_lo, py_hi),
                                            (px_lo, px_hi)))
    Hp, Wp = xp.shape
    wq_pad = sum(xd.out_size for xd in x_dims)

    machine = GanaxMachine(n_pvs, pes_per_pv)
    machine.load_memory("input", xp)
    machine.load_memory("weight", np.asarray(w, np.float64))

    programs, reorg_rows = compile_tconv_program(
        sched, n_pvs, pes_per_pv, wq_pad, Wp)

    # Reorganized output buffer: one row of width wq_pad per reorg row.
    machine.load_memory("output", np.zeros((len(reorg_rows), wq_pad)))
    stats_acc = machine.run(programs, mimd=mimd)
    stats_acc["utilization_mac_only"] = (
        stats_acc["macs"] / (max(stats_acc["pv_cycles"]) * n_pvs *
                             pes_per_pv)
        if stats_acc["pv_cycles"] else 0.0)
    out_buf = machine.mem["output"].reshape(len(reorg_rows), wq_pad)

    # De-reorganize: reorg row (y_phase, qy) and column block (x_phase, qx)
    # map to output (qy*s_y + y_phase, qx*s_x + x_phase).
    H_out, W_out = sched.out_sizes
    out = np.zeros((H_out, W_out), np.float64)
    x_base = {}
    acc = 0
    for xd in x_dims:
        x_base[xd.phase] = acc
        acc += xd.out_size
    for r, (y_phase, qy) in enumerate(reorg_rows):
        oy = qy * sched.strides[0] + y_phase
        for xd in x_dims:
            qs = np.arange(xd.out_size)
            out[oy, qs * sched.strides[1] + xd.phase] = \
                out_buf[r, x_base[xd.phase]: x_base[xd.phase] + xd.out_size]
    return out, stats_acc
