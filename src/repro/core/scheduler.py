"""Static GANAX schedule generation (the paper's "μop compilation" stage).

The paper statically translates each (transposed-)convolution layer into a
set of microprograms: output rows are grouped by their zero-pattern
("output row reorganization", Fig. 5a), filter rows are regrouped to match
("filter row reorganization", Fig. 5b), and the resulting per-group programs
are preloaded into the global/local μop buffers.

On TPU this corresponds exactly to the *polyphase decomposition* of the
transposed convolution.  For a stride-``s`` transposed conv with kernel size
``K`` and padding ``p`` (PyTorch/``lax.conv_transpose`` semantics), output
position ``o`` receives contributions only from kernel taps

    k ≡ (o + p) (mod s),

so output positions fall into ``s`` *phases* ``φ = o mod s`` per spatial
dimension, and each phase is a **dense** correlation between the
(un-expanded!) input and a strided sub-sampling of the kernel taps.  The
number of taps varies per phase — the paper's "variable number of operations
per convolution window" — which is what forces MIMD-SIMD execution.

This module computes, ahead of time and with pure Python/numpy (it runs at
trace time; nothing here is traced):

* per-phase tap lists, tap counts, input offsets, paddings and
  phase-plane output sizes (`PhaseDim`, `PhaseSchedule`);
* flattened, padded tap tables for the Pallas kernel's scalar-prefetch
  arguments (the "local μop buffer" contents);
* MAC statistics used by the analytical model (consequential vs. total).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "PhaseDim",
    "PhaseSchedule",
    "make_schedule",
    "transposed_conv_output_size",
]


def transposed_conv_output_size(in_size: int, kernel: int, stride: int,
                                padding: int, output_padding: int = 0) -> int:
    """Output size of a transposed convolution (PyTorch semantics)."""
    return stride * (in_size - 1) + kernel - 2 * padding + output_padding


@dataclasses.dataclass(frozen=True)
class PhaseDim:
    """Per-dimension data for one output phase ``φ`` (``o ≡ φ mod s``).

    Attributes:
      phase: the phase index ``φ`` in ``[0, stride)``.
      taps: original kernel tap indices contributing to this phase,
        ascending (``k = c, c+s, c+2s, ...``).
      n_taps: ``len(taps)`` — the per-phase "microprogram length".
      offset: ``m(φ) = (φ + p - c(φ)) // s``; contribution ``t`` (indexing
        ``taps``) reads input position ``q + offset - t`` for phase-plane
        output position ``q``.
      out_size: size of this phase's output plane
        (``ceil((out_size_total - φ)/s)``).
      pad_lo / pad_hi: zero padding of the *input* so that the dense
        sub-correlation stays in bounds: position ``q`` reads padded input
        ``[q, q + n_taps)`` when correlating with the reversed tap order.
    """

    phase: int
    taps: tuple[int, ...]
    n_taps: int
    offset: int
    out_size: int
    pad_lo: int
    pad_hi: int


@dataclasses.dataclass(frozen=True)
class PhaseSchedule:
    """Complete static schedule for an N-D transposed convolution.

    ``dims[d][φ]`` is the `PhaseDim` for spatial dim ``d`` phase ``φ``.
    ``phase_order`` lists multi-dim phases longest-microprogram-first (the
    equal-work MIMD scheduling heuristic: long programs issue first so the
    pipeline tail is short).
    """

    in_sizes: tuple[int, ...]
    kernel: tuple[int, ...]
    strides: tuple[int, ...]
    paddings: tuple[int, ...]
    out_sizes: tuple[int, ...]
    dims: tuple[tuple[PhaseDim, ...], ...]

    # -- derived -----------------------------------------------------------
    @property
    def n_dims(self) -> int:
        return len(self.in_sizes)

    @property
    def n_phases(self) -> int:
        return int(np.prod([len(d) for d in self.dims]))

    def phase_tuple(self, flat: int) -> tuple[int, ...]:
        """Unflatten a phase id (row-major over dims)."""
        out = []
        for dim in reversed(self.dims):
            out.append(flat % len(dim))
            flat //= len(dim)
        return tuple(reversed(out))

    def phase_dims(self, flat: int) -> tuple[PhaseDim, ...]:
        return tuple(self.dims[d][φ]
                     for d, φ in enumerate(self.phase_tuple(flat)))

    @property
    def phase_order(self) -> tuple[int, ...]:
        """Phases ordered longest-first by total tap count."""
        def work(i: int) -> int:
            return int(np.prod([pd.n_taps for pd in self.phase_dims(i)]))
        return tuple(sorted(range(self.n_phases), key=work, reverse=True))

    @property
    def max_taps(self) -> tuple[int, ...]:
        return tuple(max(pd.n_taps for pd in dim) for dim in self.dims)

    @property
    def phase_out_sizes(self) -> tuple[tuple[int, ...], ...]:
        return tuple(tuple(pd.out_size for pd in self.phase_dims(i))
                     for i in range(self.n_phases))

    # -- MAC statistics (paper Fig. 1) --------------------------------------
    def consequential_macs(self, cin: int, cout: int, batch: int = 1) -> int:
        """MACs actually contributing to the output (non-zero operands)."""
        total = 0
        for i in range(self.n_phases):
            pds = self.phase_dims(i)
            pix = int(np.prod([pd.out_size for pd in pds]))
            taps = int(np.prod([pd.n_taps for pd in pds]))
            total += pix * taps
        return total * cin * cout * batch

    def zero_inserted_macs(self, cin: int, cout: int, batch: int = 1) -> int:
        """MACs a conventional conv dataflow performs on the zero-inserted
        input (the EYERISS-style baseline cost)."""
        pix = int(np.prod(self.out_sizes))
        taps = int(np.prod(self.kernel))
        return pix * taps * cin * cout * batch

    def inconsequential_fraction(self) -> float:
        """Fraction of baseline MACs that are wasted on inserted zeros
        (paper Fig. 1)."""
        c = self.consequential_macs(1, 1)
        z = self.zero_inserted_macs(1, 1)
        return 1.0 - c / z if z else 0.0

    # -- Pallas scalar-prefetch tables ("local μop buffer" contents) --------
    def tap_tables(self) -> dict[str, np.ndarray]:
        """Flattened per-phase tables, padded to the max tap count.

        Returns int32 arrays (first axis = flat phase id, in ``phase_order``
        so the kernel grid walks longest-first):
          n_taps:      (P,)            total taps (product over dims)
          tap_dx:      (P, T_max, D)   input offset per tap per dim
                        (pre-composed with per-phase padding so offsets are
                        always >= 0 into the padded input)
          tap_k:       (P, T_max, D)   original kernel tap index per dim
          out_base:    (P, D)          first output coordinate (== phase φ)
          out_size:    (P, D)          phase-plane output sizes
          pad_lo:      (P, D)          input left-padding per dim
        """
        D = self.n_dims
        order = self.phase_order
        P = self.n_phases
        t_max = int(np.prod(self.max_taps))
        n_taps = np.zeros((P,), np.int32)
        tap_dx = np.zeros((P, t_max, D), np.int32)
        tap_k = np.zeros((P, t_max, D), np.int32)
        out_base = np.zeros((P, D), np.int32)
        out_size = np.zeros((P, D), np.int32)
        pad_lo = np.zeros((P, D), np.int32)
        # Uniform padding across phases (max over phases per dim) so a single
        # padded input works for every phase:
        upad_lo = [max(pd.pad_lo for pd in dim) for dim in self.dims]
        for row, flat in enumerate(order):
            pds = self.phase_dims(flat)
            per_dim_taps = []
            for d, pd in enumerate(pds):
                # tap t reads padded_input[q + upad_lo + offset - t]
                # → store dx(t) = upad_lo[d] + pd.offset - t  (>= 0 by
                #   construction of pad_lo).
                taps_d = [(upad_lo[d] + pd.offset - t, pd.taps[t])
                          for t in range(pd.n_taps)]
                per_dim_taps.append(taps_d)
                out_base[row, d] = pd.phase
                out_size[row, d] = pd.out_size
                pad_lo[row, d] = upad_lo[d]
            # Cartesian product of per-dim taps, row-major.
            combos = [[]]
            for taps_d in per_dim_taps:
                combos = [c + [t] for c in combos for t in taps_d]
            n_taps[row] = len(combos)
            for ti, combo in enumerate(combos):
                for d, (dx, k) in enumerate(combo):
                    tap_dx[row, ti, d] = dx
                    tap_k[row, ti, d] = k
        return dict(n_taps=n_taps, tap_dx=tap_dx, tap_k=tap_k,
                    out_base=out_base, out_size=out_size, pad_lo=pad_lo)

    def uniform_padding(self) -> tuple[tuple[int, int], ...]:
        """(lo, hi) input padding per dim covering every phase's needs."""
        return tuple(
            (max(pd.pad_lo for pd in dim), max(pd.pad_hi for pd in dim))
            for dim in self.dims)


def _phase_dim(in_size: int, kernel: int, stride: int, padding: int,
               phase: int, out_size_total: int) -> PhaseDim:
    c = (phase + padding) % stride
    taps = tuple(range(c, kernel, stride))
    n = len(taps)
    offset = (phase + padding - c) // stride
    out_size = max(0, -(-(out_size_total - phase) // stride))
    # position q reads input[q + offset - t], t in [0, n)
    pad_lo = max(0, (n - 1) - offset)
    pad_hi = max(0, (out_size - 1 + offset) - (in_size - 1))
    return PhaseDim(phase=phase, taps=taps, n_taps=n, offset=offset,
                    out_size=out_size, pad_lo=pad_lo, pad_hi=pad_hi)


def make_schedule(in_sizes: Sequence[int], kernel: Sequence[int],
                  strides: Sequence[int], paddings: Sequence[int],
                  output_paddings: Sequence[int] | None = None
                  ) -> PhaseSchedule:
    """Build the static GANAX schedule for an N-D transposed convolution.

    A stride-1 schedule degenerates to a single phase == plain convolution
    (the paper's "SIMD mode"); stride > 1 produces the multi-phase
    "MIMD-SIMD mode".
    """
    in_sizes = tuple(int(x) for x in in_sizes)
    kernel = tuple(int(x) for x in kernel)
    strides = tuple(int(x) for x in strides)
    paddings = tuple(int(x) for x in paddings)
    if output_paddings is None:
        output_paddings = (0,) * len(in_sizes)
    output_paddings = tuple(int(x) for x in output_paddings)
    if not (len(in_sizes) == len(kernel) == len(strides) == len(paddings)
            == len(output_paddings)):
        raise ValueError("dimension mismatch between schedule arguments")
    for k, s, p in zip(kernel, strides, paddings):
        if s < 1 or k < 1 or p < 0:
            raise ValueError(f"invalid tconv geometry k={k} s={s} p={p}")
        if p >= k:
            raise ValueError(f"padding {p} >= kernel {k} unsupported")
    out_sizes = tuple(
        max(0, transposed_conv_output_size(i, k, s, p, op))
        for i, k, s, p, op in zip(in_sizes, kernel, strides, paddings,
                                  output_paddings))
    dims = []
    for d in range(len(in_sizes)):
        dims.append(tuple(
            _phase_dim(in_sizes[d], kernel[d], strides[d], paddings[d],
                       φ, out_sizes[d])
            for φ in range(strides[d])))
    return PhaseSchedule(in_sizes=in_sizes, kernel=kernel, strides=strides,
                         paddings=paddings, out_sizes=out_sizes,
                         dims=tuple(dims))
