"""Unified dataflow dispatch for the GANAX (transposed-)convolution ops.

This module is the single entry point to every executable dataflow in the
repo.  It owns three things:

1. **Backend registry** — the four execution paths (``pallas-tpu``,
   ``pallas-interpret``, ``polyphase``, ``zero-insert``) are registered
   :class:`Backend` objects; callers select them with one
   :class:`DataflowPolicy` value (auto-selection by platform/ndim, an
   explicit override, or a per-call escape hatch) instead of the old
   scattered ``use_pallas``/``force_pallas`` booleans.

2. **μop compilation cache** — the static "μop compilation" stage
   (``PhaseSchedule`` construction, tap tables, per-phase weight-gather
   indices, the uniform padding plan) is pure geometry and used to be
   recomputed on every trace.  :func:`compile_uops` /
   :func:`compile_conv_uops` hoist it behind an LRU cache keyed on
   ``(in_spatial, kernel, strides, paddings)`` returning frozen numpy
   artifacts, so repeated layers and re-traces (train step, serve engine,
   benchmark sweeps) pay the scheduler once.

3. **Custom VJP** — on the kernel backends, :func:`tconv` /
   :func:`conv` carry a ``jax.custom_vjp`` exploiting the conv/tconv
   adjoint duality: the input-cotangent of a stride-``s`` transposed
   conv is a stride-``s`` plain conv with channel-swapped kernel (and
   vice versa), so the input-gradient re-enters the *same* unified
   kernel with a derived schedule (the weight gradient is a dense
   tap-indexed contraction with no inserted zeros, computed on the XLA
   path — see ``_tconv_wgrad``).  This makes the Pallas kernel (which
   has no autodiff rule) trainable, and keeps zero-skipping in both the
   forward and backward passes.  The pure-JAX backends keep XLA's
   native autodiff, which is already fused (and, for polyphase,
   already zero-skipping — the backward of a phase conv is a phase
   conv).

4. **Fused epilogue** — an :class:`Epilogue` (bias add + activation)
   is a first-class argument of :func:`tconv` / :func:`conv`.  On the
   kernel backends it executes inside the Pallas accumulator flush, so
   the raw accumulator never round-trips through HBM just to have two
   elementwise ops applied; the pure-JAX backends apply the identical
   epilogue after the op (XLA fuses it natively), keeping all four
   backends bit-comparable.  Fused configs stay trainable: the fused
   custom VJP recovers the activation derivative from the *saved
   output* (``relu``/``leaky_relu``/``tanh`` are all invertible-slope
   activations) and reduces the pre-activation cotangent into the bias
   gradient, so no pre-activation tensor is ever materialized.

Geometry semantics are PyTorch ``ConvTranspose`` / correlation-conv
throughout (channels-last ``x``, ``(K..., Cin, Cout)`` weights), matching
``core.tconv`` and ``core.scheduler``.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core.scheduler import PhaseSchedule, make_schedule
from repro.core.tconv import tconv_ganax, tconv_zero_insert

__all__ = [
    "Backend",
    "DataflowPolicy",
    "Epilogue",
    "Resolution",
    "ACTIVATIONS",
    "SHARDINGS",
    "COUT_SHARD_MIN_BYTES",
    "choose_layer_sharding",
    "pallas_kernel_supported",
    "backend_supports",
    "blocks_valid",
    "resolve_execution",
    "CompiledUops",
    "ConvUops",
    "register_backend",
    "available_backends",
    "compile_uops",
    "compile_conv_uops",
    "uop_cache_info",
    "uop_cache_clear",
    "tconv",
    "conv",
    "SecondOrderNotImplemented",
]


# ---------------------------------------------------------------------------
# Fused epilogue spec.
# ---------------------------------------------------------------------------

ACTIVATIONS = ("none", "relu", "leaky_relu", "tanh")


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Per-layer epilogue fused into the unified (t)conv op.

    ``bias`` adds a per-output-channel bias vector (supplied as the
    ``bias=`` argument of :func:`tconv` / :func:`conv`); ``activation``
    is applied after it.  On the kernel backends both run inside the
    Pallas accumulator flush; the pure-JAX backends apply :meth:`apply`
    after the op, so every backend computes the same function.

    The spec is hashable (safe as a static jit / ``custom_vjp`` nondiff
    argument and as part of an autotuner plan key).  ``leaky_slope`` is
    canonicalized to the default for non-leaky activations so two specs
    that compute the same function compare (and hash) equal.
    """

    bias: bool = False
    activation: str = "none"
    leaky_slope: float = 0.2

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(f"unknown epilogue activation "
                             f"{self.activation!r}; one of {ACTIVATIONS}")
        slope = 0.2 if self.activation != "leaky_relu" \
            else float(self.leaky_slope)
        if not slope >= 0:
            # grad_from_output recovers the leaky derivative from the
            # output's sign, which requires a sign-preserving slope
            raise ValueError(f"leaky_slope must be >= 0, got {slope}")
        object.__setattr__(self, "leaky_slope", slope)

    @property
    def is_identity(self) -> bool:
        return not self.bias and self.activation == "none"

    def apply(self, y: jax.Array, bias: jax.Array | None = None
              ) -> jax.Array:
        """Reference (pure-JAX) application — the function the kernel
        backends fuse into their flush step.

        Computed in float32 and cast back to ``y.dtype``, mirroring the
        kernels' f32-accumulator flush: with low-precision storage the
        bias add and activation never run in the narrow type (a bf16
        ``y + f32 bias`` would otherwise also silently promote the
        layer output to f32).  Bit-neutral for f32 inputs."""
        dt = y.dtype
        y = y.astype(jnp.float32)
        if self.bias:
            y = y + bias.astype(jnp.float32)
        if self.activation == "relu":
            y = jax.nn.relu(y)
        elif self.activation == "leaky_relu":
            y = jax.nn.leaky_relu(y, self.leaky_slope)
        elif self.activation == "tanh":
            y = jnp.tanh(y)
        return y.astype(dt)

    def grad_from_output(self, y: jax.Array) -> jax.Array:
        """The activation derivative recovered from the saved *output*
        ``y = act(z)`` — every supported activation is sign-preserving
        (relu/leaky) or smoothly invertible (tanh: act' = 1 - y²), so
        the fused VJP never needs the pre-activation tensor."""
        if self.activation == "relu":
            return (y > 0).astype(y.dtype)
        if self.activation == "leaky_relu":
            return jnp.where(y > 0, jnp.ones_like(y),
                             jnp.asarray(self.leaky_slope, y.dtype))
        if self.activation == "tanh":
            return 1.0 - jnp.square(y)
        return jnp.ones_like(y)

    def key_fields(self) -> dict:
        """The epilogue's contribution to an autotuner plan key."""
        return {"bias": self.bias, "activation": self.activation,
                "leaky_slope": self.leaky_slope}

    def describe(self) -> str:
        parts = []
        if self.activation != "none":
            parts.append(self.activation
                         if self.activation != "leaky_relu"
                         else f"leaky_relu({self.leaky_slope:g})")
        if self.bias:
            parts.append("bias")
        return "+".join(parts) or "none"


_IDENTITY_EPILOGUE = Epilogue()


def _canonical_epilogue(epilogue: Epilogue | None,
                        bias: jax.Array | None, w: jax.Array
                        ) -> Epilogue:
    """Validate the (epilogue, bias) pair of one dispatch; a bare
    ``bias=`` array with no epilogue means a plain fused bias add."""
    if epilogue is None:
        epilogue = Epilogue(bias=True) if bias is not None \
            else _IDENTITY_EPILOGUE
    if epilogue.bias and bias is None:
        raise ValueError("epilogue.bias=True but no bias= array passed")
    if not epilogue.bias and bias is not None:
        raise ValueError("bias= passed but epilogue.bias=False")
    if bias is not None and tuple(bias.shape) != (w.shape[-1],):
        raise ValueError(f"bias must have shape (cout,)=({w.shape[-1]},), "
                         f"got {tuple(bias.shape)}")
    return epilogue


# ---------------------------------------------------------------------------
# μop compilation cache (frozen static artifacts, keyed on geometry).
# ---------------------------------------------------------------------------

def _frozen(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(a)
    a.setflags(write=False)
    return a


@dataclasses.dataclass(frozen=True)
class CompiledUops:
    """Frozen static schedule artifacts for one tconv geometry.

    ``schedule`` serves every backend; the remaining fields are the
    kernel-ready "local μop buffer" contents for the 2-D and 3-D Pallas
    paths (``None`` for other ranks): flattened tap tables, per-phase
    weight-gather indices, and the uniform input padding plan.
    ``tap_dz`` is the depth offset table of the volumetric kernel and is
    ``None`` for 2-D geometries.
    """

    schedule: PhaseSchedule
    # -- Pallas prep (2-D / 3-D) --------------------------------------------
    n_taps: np.ndarray | None       # (P,)
    tap_dy: np.ndarray | None       # (P, T)
    tap_dx: np.ndarray | None       # (P, T)
    k_idx: np.ndarray | None        # (P, T) flattened kernel tap index
    valid: np.ndarray | None        # (P, T) tap-validity mask
    pad: tuple[tuple[int, int], ...] | None   # per-spatial-dim input padding
    q_sizes: tuple[int, ...] | None           # phase-plane grid (ceil(out/s))
    tap_dz: np.ndarray | None = None          # (P, T), 3-D only


@dataclasses.dataclass(frozen=True)
class ConvUops:
    """Frozen single-phase (SIMD-mode) tables for a plain strided conv.
    ``tap_dz`` is ``None`` for 2-D geometries."""

    out_sizes: tuple[int, ...]
    n_taps: np.ndarray              # (1,)
    tap_dy: np.ndarray              # (1, prod(kernel))
    tap_dx: np.ndarray              # (1, prod(kernel))
    pad: tuple[tuple[int, int], ...]
    tap_dz: np.ndarray | None = None    # (1, prod(kernel)), 3-D only


@functools.lru_cache(maxsize=512)
def compile_uops(in_spatial: tuple[int, ...], kernel: tuple[int, ...],
                 strides: tuple[int, ...], paddings: tuple[int, ...]
                 ) -> CompiledUops:
    """Run the static μop compilation once per layer geometry."""
    sched = make_schedule(in_spatial, kernel, strides, paddings)
    nd = sched.n_dims
    if nd not in (2, 3):
        return CompiledUops(schedule=sched, n_taps=None, tap_dy=None,
                            tap_dx=None, k_idx=None, valid=None, pad=None,
                            q_sizes=None)
    tables = sched.tap_tables()
    tap_off = tables["tap_dx"]          # (P, T, nd)
    tap_k = tables["tap_k"]             # (P, T, nd)
    n_taps = tables["n_taps"]           # (P,)
    t_max = tap_off.shape[1]

    # Row-major flattened kernel tap index over all spatial dims.
    k_idx = tap_k[..., 0]
    for d in range(1, nd):
        k_idx = k_idx * kernel[d] + tap_k[..., d]             # (P, T)
    valid = np.arange(t_max)[None, :] < n_taps[:, None]
    k_idx = np.where(valid, k_idx, 0)

    # Uniform padding, extended so every (offset + q) window slice stays
    # in bounds (the kernel walks phase planes with unit window stride).
    q_sizes = tuple(-(-o // s) for o, s in zip(sched.out_sizes, strides))
    upad = sched.uniform_padding()
    pad = []
    for d in range(nd):
        lo, hi = upad[d]
        need = int(tap_off[..., d].max()) + (q_sizes[d] - 1) + 1
        extent = in_spatial[d] + lo + hi
        pad.append((lo, hi + max(0, need - extent)))
    offs = {f"tap_d{ax}": _frozen(tap_off[..., d])
            for d, ax in enumerate("zyx"[-nd:])}
    return CompiledUops(
        schedule=sched,
        n_taps=_frozen(n_taps),
        k_idx=_frozen(k_idx.astype(np.int32)),
        valid=_frozen(valid),
        pad=tuple(pad),
        q_sizes=q_sizes,
        **offs,
    )


@functools.lru_cache(maxsize=512)
def compile_conv_uops(in_spatial: tuple[int, ...],
                      kernel: tuple[int, ...], strides: tuple[int, ...],
                      paddings: tuple[int, ...]) -> ConvUops:
    """Single-phase tap tables for a 2-D/3-D plain conv (the paper's SIMD
    mode: one microprogram whose taps are the full kernel)."""
    nd = len(in_spatial)
    if not pallas_kernel_supported(nd):
        raise ValueError(f"conv μop tables exist only for the kernel's "
                         f"spatial ranks (2-D/3-D), got {nd}-D")
    out_sizes = tuple((i + 2 * p - k) // s + 1
                      for i, k, s, p in zip(in_spatial, kernel, strides,
                                            paddings))
    t_max = int(np.prod(kernel))
    taps = np.stack([np.asarray(u, np.int32)
                     for u in np.ndindex(*kernel)])       # (T, nd)
    pad = tuple(
        (p, max(0, (k - 1) + (q - 1) * s + 1 - (i + p)))
        for i, k, s, p, q in zip(in_spatial, kernel, strides, paddings,
                                 out_sizes))
    offs = {f"tap_d{ax}": _frozen(taps[None, :, d])
            for d, ax in enumerate("zyx"[-nd:])}
    return ConvUops(out_sizes=out_sizes,
                    n_taps=_frozen(np.asarray([t_max], np.int32)),
                    pad=pad, **offs)


def uop_cache_info() -> dict[str, int]:
    """Aggregate hit/miss counters over both μop caches."""
    a, b = compile_uops.cache_info(), compile_conv_uops.cache_info()
    return {"hits": a.hits + b.hits, "misses": a.misses + b.misses,
            "currsize": a.currsize + b.currsize}


def uop_cache_clear() -> None:
    compile_uops.cache_clear()
    compile_conv_uops.cache_clear()


# Observers (the train loop's end-of-run stats, ``obs.collect``) read
# the μop-cache efficiency through the obs registry instead of poking
# this module's privates.
_obs.register_collector("dataflow.uop_cache", uop_cache_info)


# ---------------------------------------------------------------------------
# Backend registry.
# ---------------------------------------------------------------------------

def _any_rank(nd: int) -> bool:
    return True


@dataclasses.dataclass(frozen=True)
class Backend:
    """One executable dataflow: a tconv and a conv implementation.

    ``tconv`` / ``conv`` take ``(x, w, strides, paddings)`` (plus the
    resolved ``interpret`` flag for kernel backends) and return the output;
    ``supports`` gates dispatch on the spatial rank.
    """

    name: str
    tconv: Callable[..., jax.Array]
    conv: Callable[..., jax.Array]
    supports: Callable[[int], bool] = _any_rank


_BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> None:
    _BACKENDS[backend.name] = backend


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def backend_supports(name: str, nd: int) -> bool:
    """True when registered backend ``name`` executes ``nd``-spatial ops
    (used by the autotuner's candidate enumerator and plan validation)."""
    b = _BACKENDS.get(name)
    return b is not None and b.supports(nd)


def pallas_kernel_supported(nd: int) -> bool:
    """Spatial ranks the Pallas kernel implements (single source of
    truth for both dispatch and the ops-level guards): planar (2-D) and
    volumetric (3-D) layers."""
    return nd in (2, 3)


def _conv_dense(x, w, strides, paddings):
    from repro.kernels.ref import conv_ref
    return conv_ref(x, w, strides, paddings)


def _tconv_polyphase(x, w, strides, paddings):
    nd = x.ndim - 2
    u = compile_uops(x.shape[1:1 + nd], w.shape[:nd], tuple(strides),
                     tuple(paddings))
    return tconv_ganax(x, w, strides, paddings, schedule=u.schedule)


def _pallas(interpret: bool, transposed: bool):
    def fn(x, w, strides, paddings, blocks=None, epilogue=None, bias=None):
        from repro.kernels.ops import ganax_conv, ganax_conv_transpose
        op = ganax_conv_transpose if transposed else ganax_conv
        return op(x, w, strides, paddings, interpret=interpret,
                  blocks=blocks, epilogue=epilogue, bias=bias)
    return fn


register_backend(Backend(
    name="zero-insert", tconv=tconv_zero_insert, conv=_conv_dense))
register_backend(Backend(
    name="polyphase", tconv=_tconv_polyphase, conv=_conv_dense))
register_backend(Backend(
    name="pallas-interpret", tconv=_pallas(True, True),
    conv=_pallas(True, False), supports=pallas_kernel_supported))
register_backend(Backend(
    name="pallas-tpu", tconv=_pallas(False, True),
    conv=_pallas(False, False), supports=pallas_kernel_supported))


# ---------------------------------------------------------------------------
# Policy.
# ---------------------------------------------------------------------------

def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class DataflowPolicy:
    """How to pick an execution path for the unified (t)conv ops.

    ``backend``:
      * ``None`` (heuristic) — Pallas on TPU for 2-D layers, polyphase
        otherwise (the production default: interpret-mode Pallas is a
        correctness tool, not a fast path).
      * ``"auto"`` — measurement-driven: at dispatch time the op consults
        the autotuning :class:`repro.tune.Planner` for a plan keyed on
        (layer geometry, dtype, platform); a hit executes the measured
        best backend *and* its tuned Pallas block shapes, a miss falls
        back to the ``None`` heuristic.  The planner never measures at
        dispatch (dispatch may be inside a ``jit`` trace) — plans come
        from ``python -m repro.tune``, ``GanServer`` construction
        warmup, or an explicit ``Planner.plan`` call, persisted via the
        planner's JSON plan file.  Resolution order is therefore
        *pinned > auto(planned) > heuristic*.
      * ``"pallas"`` — the unified kernel, interpret off-TPU, with a
        polyphase fallback for ranks the kernel doesn't support (the
        legacy ``use_pallas=True`` behavior).
      * ``"pallas-tpu"`` / ``"pallas-interpret"`` / ``"polyphase"`` /
        ``"zero-insert"`` — that registered backend exactly (strict:
        unsupported rank raises).

    ``interpret`` requests the Pallas kernel in interpret (``True``) or
    compiled (``False``) mode regardless of platform; with an auto or
    ``"pallas"`` backend it implies the kernel, keeping the polyphase
    fallback for ranks the kernel doesn't support.  Combined with an
    explicitly pinned backend it must agree — a contradiction (e.g.
    ``backend="pallas-tpu", interpret=True``) raises.
    ``differentiable=True`` (default) guarantees gradients on every
    backend: the kernel backends — which have no autodiff rule — get the
    custom VJP; the pure-JAX backends keep XLA's native (already fused,
    and for polyphase already zero-skipping) autodiff.
    ``differentiable=False`` drops that guarantee for the kernel
    backends (forward-only serving/benchmark escape hatch).

    The policy is hashable, so it is safe as a static jit argument and as
    part of a ``custom_vjp`` nondiff argument.
    """

    backend: str | None = None
    interpret: bool | None = None
    differentiable: bool = True

    @classmethod
    def from_legacy(cls, dataflow: str = "ganax",
                    use_pallas: bool = False) -> "DataflowPolicy":
        """Interpret the historic ``GanConfig`` flag pair.  This is the
        only place the legacy booleans are given meaning.

        Deprecated: ``GanConfig(backend=...)`` (any registered backend
        name, ``"pallas"``, or ``"auto"``) is the supported knob; the
        legacy pair survives only for old configs and warns when set to
        a non-default value."""
        if dataflow != "ganax" or use_pallas:
            warnings.warn(
                "the legacy GanConfig dataflow=/use_pallas= fields are "
                "deprecated; select the execution path with "
                "GanConfig(backend=...) (a registered backend name, "
                "'pallas', or 'auto') instead",
                DeprecationWarning, stacklevel=3)
        if dataflow == "zero_insert":
            return cls(backend="zero-insert")
        if dataflow != "ganax":
            raise ValueError(f"unknown dataflow {dataflow!r}")
        return cls(backend="pallas") if use_pallas else \
            cls(backend="polyphase")

    def resolve(self, nd: int) -> str:
        """Pick the concrete backend name for an ``nd``-spatial op.

        Geometry-free resolution: ``backend="auto"`` reports the
        heuristic choice here (the planner needs full layer geometry,
        which only the dispatch functions have)."""
        name = self.backend
        if name == "auto":
            if self.interpret is not None:
                raise ValueError(
                    "interpret cannot be combined with backend='auto': "
                    "the planner owns the kernel-variant choice")
            name = None
        if self.interpret is not None and name is None:
            # an interpret request implies the Pallas kernel (with the
            # usual rank fallback), not whatever auto would pick
            name = "pallas"
        if name is None:
            name = "pallas-tpu" if (_on_tpu() and
                                    pallas_kernel_supported(nd)) \
                else "polyphase"
        elif name == "pallas":
            if pallas_kernel_supported(nd):
                name = "pallas-tpu" if _on_tpu() else "pallas-interpret"
            else:
                name = "polyphase"
        if self.interpret is not None:
            if self.backend in (None, "pallas"):
                # preference forms: the interpret request picks the
                # kernel variant (rank fallback to polyphase untouched)
                if name.startswith("pallas"):
                    name = ("pallas-interpret" if self.interpret
                            else "pallas-tpu")
            else:
                # explicit names are strict: a pinned backend that
                # disagrees with the interpret request is a
                # contradiction, not an override
                expected = ("pallas-interpret" if self.interpret
                            else "pallas-tpu")
                if name != expected:
                    raise ValueError(
                        f"interpret={self.interpret} contradicts "
                        f"backend={self.backend!r}")
        if name not in _BACKENDS:
            raise ValueError(f"unknown dataflow backend {name!r}; "
                             f"available: {available_backends()}")
        if not _BACKENDS[name].supports(nd):
            raise ValueError(f"backend {name!r} does not support "
                             f"{nd}-D spatial inputs")
        return name


# ---------------------------------------------------------------------------
# Unified ops + custom VJP.
# ---------------------------------------------------------------------------

class SecondOrderNotImplemented(NotImplementedError):
    pass


_SECOND_ORDER_MSG = (
    "second-order (and forward-mode) autodiff through the unified GANAX "
    "(t)conv op is not implemented on the kernel backends: their "
    "jax.custom_vjp defines a single backward pass, so grad-of-grad "
    "(hessian, etc.) would need derivatives of the Pallas kernel itself. "
    "Differentiate through a pure-JAX backend instead — "
    "DataflowPolicy(backend='polyphase') or 'zero-insert' keep XLA's "
    "native autodiff, which supports arbitrary-order derivatives.")


def _reject_higher_order(x, w) -> None:
    """Kernel backends have no JVP rule: a JVP tracer reaching one means
    the custom VJP's single backward pass is itself being differentiated
    (grad-of-grad) or forward-mode is being applied.  Without this check
    that surfaces as a bare NotImplementedError from deep inside
    pallas_call; raise the actionable error instead."""
    from jax.interpreters import ad
    if isinstance(x, ad.JVPTracer) or isinstance(w, ad.JVPTracer):
        raise SecondOrderNotImplemented(_SECOND_ORDER_MSG)


def _run(backend: str, transposed: bool, x, w, strides, paddings,
         blocks=None, epilogue: Epilogue | None = None, bias=None):
    ep = epilogue or _IDENTITY_EPILOGUE
    b = _BACKENDS[backend]
    fn = b.tconv if transposed else b.conv
    if backend.startswith("pallas"):
        _reject_higher_order(x, w)
        return fn(x, w, strides, paddings, blocks=blocks,
                  epilogue=None if ep.is_identity else ep, bias=bias)
    if blocks is not None:
        raise ValueError(f"blocks={blocks!r} only applies to the Pallas "
                         f"kernel backends, not {backend!r}")
    y = fn(x, w, strides, paddings)
    # Pure-JAX backends: the same epilogue, applied after the op — XLA
    # fuses it natively and keeps native autodiff through it.
    return y if ep.is_identity else ep.apply(y, bias)


@jax.custom_vjp
def _first_order_only(x):
    """Identity marking the custom-VJP cotangents: differentiating it
    (i.e. taking a second derivative of the unified op) raises instead of
    producing silently wrong higher-order terms."""
    return x


def _foo_fwd(x):
    return x, None


def _foo_bwd(_, g):
    raise SecondOrderNotImplemented(_SECOND_ORDER_MSG)


_first_order_only.defvjp(_foo_fwd, _foo_bwd)


def _swap_io(w: jax.Array) -> jax.Array:
    """(K..., Cin, Cout) → (K..., Cout, Cin): the adjoint's kernel."""
    return jnp.swapaxes(w, -1, -2)


def _flat_sp(a: jax.Array) -> jax.Array:
    """(N, *spatial, C) → (N, prod(spatial), C)."""
    return a.reshape(a.shape[0], -1, a.shape[-1])


def _tconv_wgrad(x, g, kernel, strides, paddings):
    """dL/dw for ``y = tconv(x, w)``:  dw[u,ci,co] = Σ_{n,i} x[n,i,ci] ·
    g[n, s·i + u - p, co] — a dense tap-indexed contraction with no
    inserted zeros (every product is a consequential MAC)."""
    nd = x.ndim - 2
    in_sp = x.shape[1:1 + nd]
    gp = jnp.pad(g, ((0, 0),) + tuple((p, p) for p in paddings) + ((0, 0),))
    xf = _flat_sp(x)
    rows = []
    for u in np.ndindex(*kernel):
        slc = (slice(None),) + tuple(
            slice(u[d], u[d] + strides[d] * (in_sp[d] - 1) + 1, strides[d])
            for d in range(nd)) + (slice(None),)
        rows.append(jnp.einsum("nsc,nso->co", xf, _flat_sp(gp[slc]),
                               preferred_element_type=jnp.float32))
    return jnp.stack(rows).reshape(tuple(kernel) + rows[0].shape)


def _conv_wgrad(x, g, kernel, strides, paddings):
    """dL/dw for ``y = conv(x, w)``:  dw[t,ci,co] = Σ_{n,q}
    x[n, s·q + t - p, ci] · g[n,q,co]."""
    nd = x.ndim - 2
    q_sp = g.shape[1:1 + nd]
    in_sp = x.shape[1:1 + nd]
    pad = []
    for d in range(nd):
        hi = strides[d] * (q_sp[d] - 1) + kernel[d] - 1 - paddings[d] \
            - (in_sp[d] - 1)
        pad.append((paddings[d], max(0, hi)))
    xp = jnp.pad(x, ((0, 0),) + tuple(pad) + ((0, 0),))
    gf = _flat_sp(g)
    rows = []
    for t in np.ndindex(*kernel):
        slc = (slice(None),) + tuple(
            slice(t[d], t[d] + strides[d] * (q_sp[d] - 1) + 1, strides[d])
            for d in range(nd)) + (slice(None),)
        rows.append(jnp.einsum("nsc,nso->co", _flat_sp(xp[slc]), gf,
                               preferred_element_type=jnp.float32))
    return jnp.stack(rows).reshape(tuple(kernel) + rows[0].shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _tconv_diff(backend, strides, paddings, blocks, x, w):
    return _run(backend, True, x, w, strides, paddings, blocks)


def _tconv_fwd(backend, strides, paddings, blocks, x, w):
    return _run(backend, True, x, w, strides, paddings, blocks), (x, w)


def _tconv_bwd(backend, strides, paddings, blocks, res, g):
    x, w = res
    # Adjoint duality: tconv(·, w) is the adjoint of conv(·, swap(w)), so
    # dx is a plain conv — same stride/padding, same backend, derived
    # (single-phase) schedule; zero-skipping is preserved because no
    # zero-inserted tensor is ever formed.  Tuned blocks describe the
    # *forward* geometry (the adjoint has its own phase-plane/channel
    # extents), so the backward pass uses the heuristic defaults.
    dx = _run(backend, False, g, _swap_io(w), strides, paddings)
    dw = _tconv_wgrad(x, g, w.shape[:x.ndim - 2], strides, paddings)
    return (_first_order_only(dx.astype(x.dtype)),
            _first_order_only(dw.astype(w.dtype)))


_tconv_diff.defvjp(_tconv_fwd, _tconv_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _conv_diff(backend, strides, paddings, blocks, x, w):
    return _run(backend, False, x, w, strides, paddings, blocks)


def _conv_fwd(backend, strides, paddings, blocks, x, w):
    return _run(backend, False, x, w, strides, paddings, blocks), (x, w)


def _conv_dx(backend, strides, paddings, x, w, g):
    """Input-cotangent of ``y = conv(x, w)``: a transposed conv (the
    multi-phase MIMD path) — but the *uncropped* one: conv with padding
    p reads input positions [-p, s·(Q-1)+K-1-p], so the adjoint is tconv
    with padding 0 shifted by p, cropped to [0, I) with zero cotangent
    past the stride tail."""
    nd = x.ndim - 2
    dx_full = _run(backend, True, g, _swap_io(w), strides, (0,) * nd)
    slc = [slice(None)]
    pad = [(0, 0)]
    for d in range(nd):
        i_d = x.shape[1 + d]
        avail = dx_full.shape[1 + d] - paddings[d]
        slc.append(slice(paddings[d], paddings[d] + i_d))
        pad.append((0, max(0, i_d - avail)))
    slc.append(slice(None))
    pad.append((0, 0))
    return jnp.pad(dx_full[tuple(slc)], pad)


def _conv_bwd(backend, strides, paddings, blocks, res, g):
    x, w = res
    dx = _conv_dx(backend, strides, paddings, x, w, g)
    dw = _conv_wgrad(x, g, w.shape[:x.ndim - 2], strides, paddings)
    return (_first_order_only(dx.astype(x.dtype)),
            _first_order_only(dw.astype(w.dtype)))


_conv_diff.defvjp(_conv_fwd, _conv_bwd)


# -- fused-epilogue variants -------------------------------------------------
#
# ``y = act(op(x, w) + b)`` on a kernel backend.  The forward runs the
# epilogue inside the Pallas flush; the backward recovers the activation
# derivative from the saved *output* (see ``Epilogue.grad_from_output``),
# folds it into the cotangent once, and then reuses the identity-epilogue
# machinery: dx re-enters the unified kernel through the adjoint duality,
# dw is the dense tap-indexed contraction, and db is a plain reduction of
# the pre-activation cotangent over every non-channel axis.

def _epilogue_cotangent(epilogue: Epilogue, y, g):
    return g if epilogue.activation == "none" \
        else g * epilogue.grad_from_output(y)


def _bias_grad(g_pre, bias):
    # f32 accumulation: the reduction spans batch x spatial elements,
    # far too many to sum in a 8/10-bit mantissa when g_pre is stored
    # low-precision (no-op for f32 cotangents)
    axes = tuple(range(g_pre.ndim - 1))
    return jnp.sum(g_pre, axis=axes,
                   dtype=jnp.float32).astype(bias.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _tconv_ep_diff(backend, strides, paddings, blocks, epilogue, x, w, b):
    return _run(backend, True, x, w, strides, paddings, blocks,
                epilogue, b)


def _tconv_ep_fwd(backend, strides, paddings, blocks, epilogue, x, w, b):
    y = _run(backend, True, x, w, strides, paddings, blocks, epilogue, b)
    return y, (x, w, b, y)


def _tconv_ep_bwd(backend, strides, paddings, blocks, epilogue, res, g):
    x, w, b, y = res
    g_pre = _epilogue_cotangent(epilogue, y, g)
    dx = _run(backend, False, g_pre, _swap_io(w), strides, paddings)
    dw = _tconv_wgrad(x, g_pre, w.shape[:x.ndim - 2], strides, paddings)
    db = None if b is None else _first_order_only(_bias_grad(g_pre, b))
    return (_first_order_only(dx.astype(x.dtype)),
            _first_order_only(dw.astype(w.dtype)), db)


_tconv_ep_diff.defvjp(_tconv_ep_fwd, _tconv_ep_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _conv_ep_diff(backend, strides, paddings, blocks, epilogue, x, w, b):
    return _run(backend, False, x, w, strides, paddings, blocks,
                epilogue, b)


def _conv_ep_fwd(backend, strides, paddings, blocks, epilogue, x, w, b):
    y = _run(backend, False, x, w, strides, paddings, blocks, epilogue, b)
    return y, (x, w, b, y)


def _conv_ep_bwd(backend, strides, paddings, blocks, epilogue, res, g):
    x, w, b, y = res
    # the shared _conv_bwd derivation, with the pre-activation cotangent
    # in place of g
    g_pre = _epilogue_cotangent(epilogue, y, g)
    dx = _conv_dx(backend, strides, paddings, x, w, g_pre)
    dw = _conv_wgrad(x, g_pre, w.shape[:x.ndim - 2], strides, paddings)
    db = None if b is None else _first_order_only(_bias_grad(g_pre, b))
    return (_first_order_only(dx.astype(x.dtype)),
            _first_order_only(dw.astype(w.dtype)), db)


_conv_ep_diff.defvjp(_conv_ep_fwd, _conv_ep_bwd)


@dataclasses.dataclass(frozen=True)
class Resolution:
    """One layer's fully resolved execution: the concrete backend, its
    Pallas tile shapes (``None`` = heuristic defaults or a pure-JAX
    backend), the provenance of that choice, and — when the layer is
    resolved for a device mesh — how it is laid out across it.

    ``source`` is one of ``"pinned"`` (the policy named a backend or the
    kernel preference explicitly), ``"tuned"`` (a measured autotuner
    plan), or ``"heuristic"`` (the platform default, including auto-plan
    misses).  ``sharding`` is one of :data:`SHARDINGS`: ``"data"``
    (batch split over the ``data`` mesh axis, weights replicated — the
    serving-throughput layout) or ``"cout"`` (weights and bias
    additionally sharded on Cout over the ``model`` axis; the layer's
    local output is all-gathered back to full Cout, no halo exchange
    needed because Cout is a pure output dimension).  This is the data
    form of dispatch — what :class:`repro.program.ProgramSpec` freezes
    ahead of time."""

    backend: str
    blocks: tuple[int, ...] | None = None
    source: str = "heuristic"
    measured_us: float | None = None
    sharding: str = "data"


# Per-layer mesh layouts a resolution can freeze (see Resolution).
SHARDINGS = ("data", "cout")

# The footprint heuristic's default threshold: a layer whose weight
# tensor is at least this many bytes goes Cout-model-parallel on a
# mesh with model > 1 (the big 3D-GAN tconvs: g1 is 4³·512·256·4B
# ≈ 34 MiB; the small 2-D generator tails stay data-parallel where an
# all-gather would cost more than the weight traffic it saves).
COUT_SHARD_MIN_BYTES = 16 * 1024 * 1024


def choose_layer_sharding(kernel: Sequence[int], cin: int, cout: int,
                          mesh_model: int, *,
                          min_bytes: int | None = None,
                          itemsize: int = 4) -> str:
    """The footprint heuristic picking one of :data:`SHARDINGS` for a
    layer resolved against a mesh with ``mesh_model`` devices on the
    ``model`` axis.

    ``"cout"`` (weights sharded on Cout, no halo exchange) is chosen
    only when the model axis is real (> 1), Cout divides it evenly, and
    the weight footprint ``prod(kernel)·cin·cout·itemsize`` reaches
    ``min_bytes`` (default :data:`COUT_SHARD_MIN_BYTES`) — the layers
    that outgrow a single device's memory/bandwidth.  ``itemsize`` is
    the *storage* dtype's (a bf16 program's weights are half the f32
    footprint, so fewer of its layers clear the sharding threshold).
    Everything else (including every layer of a mesh-less program) is
    ``"data"``."""
    if mesh_model <= 1 or cout % mesh_model != 0:
        return "data"
    threshold = COUT_SHARD_MIN_BYTES if min_bytes is None \
        else int(min_bytes)
    weight_bytes = int(np.prod(tuple(kernel))) * int(cin) * int(cout) \
        * int(itemsize)
    return "cout" if weight_bytes >= threshold else "data"


def blocks_valid(kind: str, in_spatial: Sequence[int],
                 kernel: Sequence[int], strides: Sequence[int],
                 paddings: Sequence[int], cin: int, cout: int,
                 blocks: Sequence[int]) -> bool:
    """True when ``blocks`` divides this geometry's kernel extents —
    a stale plan (or program) entry must degrade, never raise from
    inside a trace.  ``kind`` is ``"tconv"`` or ``"conv"``."""
    from repro.kernels.ops import resolve_blocks
    in_spatial, kernel = tuple(in_spatial), tuple(kernel)
    strides, paddings = tuple(strides), tuple(paddings)
    if not pallas_kernel_supported(len(in_spatial)):
        return False
    if kind == "conv":
        u = compile_conv_uops(in_spatial, kernel, strides, paddings)
        q_lead = u.out_sizes[:-1]
    else:
        u = compile_uops(in_spatial, kernel, strides, paddings)
        q_lead = u.q_sizes[:-1]
    try:
        resolve_blocks(tuple(blocks), q_lead, int(cin), int(cout))
    except ValueError:
        return False
    return True


def resolve_execution(policy: DataflowPolicy, kind: str,
                      in_spatial: Sequence[int], kernel: Sequence[int],
                      strides: Sequence[int], paddings: Sequence[int],
                      cin: int, cout: int, *, batch: int = 1,
                      dtype="float32", epilogue: Epilogue | None = None,
                      planner=None, measure: bool = False,
                      mesh_model: int = 1,
                      cout_shard_min_bytes: int | None = None
                      ) -> Resolution:
    """Resolve one layer's execution path **as data** — the single
    resolution routine behind both the per-call dispatch and the
    ahead-of-time :mod:`repro.program` builder.

    For a non-``auto`` policy this is just ``policy.resolve`` plus
    provenance.  ``backend="auto"`` consults the autotuning planner
    (``planner`` or the process-wide one) with the full layer geometry;
    a hit yields the measured backend + tuned Pallas blocks, with stale
    plans — unknown backend, unsupported rank, blocks that no longer
    divide the geometry — degrading to the heuristic rather than
    raising.  ``measure=True`` additionally tunes plan misses (never do
    this from dispatch: it may run inside a ``jit`` trace, where timing
    is meaningless — ahead-of-time builders only).

    ``mesh_model > 1`` resolves the layer against a device mesh with
    that many devices on the ``model`` axis: :func:`choose_layer_sharding`
    picks the layout (overridable threshold via
    ``cout_shard_min_bytes``), and tuned Pallas blocks that do not
    divide the *local* Cout shard of a ``"cout"`` layer are dropped
    (reason counter ``dataflow.resolve.shard_blocks``) — the kernel
    executes per-device on ``cout / mesh_model`` channels."""
    with _obs.trace("dataflow.resolve", kind=kind) as sp:
        res, reasons = _resolve_execution(
            policy, kind, in_spatial, kernel, strides, paddings, cin,
            cout, batch=batch, dtype=dtype, epilogue=epilogue,
            planner=planner, measure=measure)
        sharding = choose_layer_sharding(
            kernel, cin, cout, mesh_model,
            min_bytes=cout_shard_min_bytes,
            itemsize=np.dtype(str(dtype)).itemsize)
        if sharding != res.sharding:
            res = dataclasses.replace(res, sharding=sharding)
        if sharding == "cout" and res.blocks is not None and \
                not blocks_valid(kind, in_spatial, kernel, strides,
                                 paddings, cin, cout // mesh_model,
                                 res.blocks):
            res = dataclasses.replace(res, blocks=None)
            reasons.append("shard_blocks")
        sp.set(backend=res.backend, source=res.source)
    _obs.counter("dataflow.resolve").inc()
    _obs.counter(f"dataflow.resolve.{res.source}").inc()
    for reason in reasons:
        _obs.counter(f"dataflow.resolve.{reason}").inc()
    return res


def _resolve_execution(policy, kind, in_spatial, kernel, strides,
                       paddings, cin, cout, *, batch, dtype, epilogue,
                       planner, measure
                       ) -> tuple[Resolution, list[str]]:
    """Uninstrumented :func:`resolve_execution` body; the second return
    value lists the plan-cache outcomes (``plan_hit``/``plan_miss``/
    ``plan_measured``) and degradations (``stale_plan``/
    ``stale_blocks``) that explain the provenance."""
    nd = len(in_spatial)
    if policy.backend != "auto":
        source = "heuristic" if policy.backend is None \
            and policy.interpret is None else "pinned"
        return Resolution(policy.resolve(nd), None, source), []
    policy.resolve(nd)  # validates the interpret combination
    from repro.tune import get_planner
    from repro.tune.planner import PlanKey
    if planner is None:
        planner = get_planner()
    ep = epilogue or _IDENTITY_EPILOGUE
    key = PlanKey(kind=kind, batch=int(batch),
                  in_spatial=tuple(int(d) for d in in_spatial),
                  kernel=tuple(int(d) for d in kernel),
                  strides=tuple(int(s) for s in strides),
                  paddings=tuple(int(p) for p in paddings),
                  cin=int(cin), cout=int(cout),
                  dtype=str(jnp.dtype(dtype)),
                  platform=jax.default_backend(),
                  **ep.key_fields())
    # Plan-cache outcome classification must not issue extra planner
    # calls — test_program pins exact ``planner.lookups`` counts — so
    # hit/miss is inferred from the measurement delta / lookup result.
    if measure:
        measured_before = planner.measurements
        plan = planner.plan(key, measure=True)
        reasons = ["plan_measured" if planner.measurements
                   > measured_before else "plan_hit"]
    else:
        plan = planner.lookup(key)
        reasons = ["plan_hit" if plan is not None else "plan_miss"]
    if plan is not None and plan.backend in _BACKENDS and \
            _BACKENDS[plan.backend].supports(nd):
        blocks = plan.blocks if plan.backend.startswith("pallas") else None
        if blocks is not None and not blocks_valid(
                kind, key.in_spatial, key.kernel, key.strides,
                key.paddings, cin, cout, blocks):
            blocks = None   # stale blocks (geometry drift): keep the
            # planned backend, fall back to its default tile shapes
            reasons.append("stale_blocks")
        source = "tuned" if plan.source == "measured" else "heuristic"
        return Resolution(plan.backend, blocks, source,
                          plan.measured_us), reasons
    if plan is not None:
        reasons.append("stale_plan")    # unknown backend / bad rank
    heuristic = dataclasses.replace(policy, backend=None).resolve(nd)
    return Resolution(heuristic, None, "heuristic"), reasons


def _planned_dispatch(policy: DataflowPolicy, transposed: bool, x, w,
                      strides, paddings,
                      epilogue: Epilogue | None = None
                      ) -> tuple[str, tuple | None]:
    """Resolve (backend, blocks) for one dispatch — the per-call form of
    :func:`resolve_execution` (lookup only, never measures)."""
    nd = x.ndim - 2
    if policy.backend != "auto":
        return policy.resolve(nd), None
    res = resolve_execution(
        policy, "tconv" if transposed else "conv",
        tuple(int(d) for d in x.shape[1:1 + nd]),
        tuple(int(d) for d in w.shape[:nd]), strides, paddings,
        int(w.shape[-2]), int(w.shape[-1]), batch=int(x.shape[0]),
        dtype=x.dtype, epilogue=epilogue)
    return res.backend, res.blocks


def tconv(x: jax.Array, w: jax.Array, strides: Sequence[int],
          paddings: Sequence[int],
          policy: DataflowPolicy | None = None,
          blocks: Sequence[int] | None = None,
          bias: jax.Array | None = None,
          epilogue: Epilogue | None = None) -> jax.Array:
    """Transposed convolution through the unified GANAX dispatch.

    x: (N, *spatial, Cin) channels-last; w: (K..., Cin, Cout).
    ``blocks`` pins the Pallas kernel tile shapes — the
    (block_qy, block_cin, block_cout) triple for 2-D layers, the
    (block_qz, block_qy, block_cin, block_cout) quadruple for volumetric
    ones — the per-call escape hatch the autotuner measures through;
    with ``backend="auto"`` the planner's tuned blocks are used instead.

    ``epilogue`` fuses a bias add (``bias``: a (Cout,) vector, required
    iff ``epilogue.bias``) and activation into the op — inside the
    Pallas accumulator flush on the kernel backends, applied post-op on
    the pure-JAX ones; a bare ``bias=`` with no epilogue means a plain
    fused bias add.  Fused configs stay differentiable (the fused
    custom VJP differentiates through the epilogue).
    """
    return _dispatch(True, x, w, strides, paddings, policy, blocks,
                     bias, epilogue)


def conv(x: jax.Array, w: jax.Array, strides: Sequence[int],
         paddings: Sequence[int],
         policy: DataflowPolicy | None = None,
         blocks: Sequence[int] | None = None,
         bias: jax.Array | None = None,
         epilogue: Epilogue | None = None) -> jax.Array:
    """Plain (strided) convolution through the same dispatch — the paper's
    SIMD mode; on kernel backends it is the degenerate single-phase case
    of the very same Pallas kernel.  ``bias``/``epilogue`` as in
    :func:`tconv`."""
    return _dispatch(False, x, w, strides, paddings, policy, blocks,
                     bias, epilogue)


def _dispatch(transposed: bool, x, w, strides, paddings, policy, blocks,
              bias, epilogue) -> jax.Array:
    policy = policy or DataflowPolicy()
    strides, paddings = tuple(strides), tuple(paddings)
    epilogue = _canonical_epilogue(epilogue, bias, w)
    if blocks is not None:
        backend = policy.resolve(x.ndim - 2)
    else:
        backend, blocks = _planned_dispatch(policy, transposed, x, w,
                                            strides, paddings, epilogue)
    blocks = tuple(blocks) if blocks is not None else None
    if policy.differentiable and backend.startswith("pallas"):
        if epilogue.is_identity:
            op = _tconv_diff if transposed else _conv_diff
            return op(backend, strides, paddings, blocks, x, w)
        op = _tconv_ep_diff if transposed else _conv_ep_diff
        return op(backend, strides, paddings, blocks, epilogue, x, w,
                  bias)
    return _run(backend, transposed, x, w, strides, paddings, blocks,
                epilogue, bias)
