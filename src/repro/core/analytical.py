"""Analytical cycle/energy model reproducing the paper's evaluation.

The paper evaluates GANAX with a cycle-level simulator over an EYERISS-like
16×16 PE array at 500 MHz, with TSMC-45nm energy numbers (Table II).  This
module implements that methodology in closed form so the paper's figures can
be reproduced quantitatively:

* Fig. 1 — fraction of inconsequential MACs per model (pure geometry; exact).
* Fig. 8 — speedup and energy reduction of generative models vs EYERISS.
* Fig. 9 — runtime/energy split between generative and discriminative models.
* Fig. 10 — energy breakdown by microarchitectural unit.
* Fig. 11 — PE utilization, EYERISS vs GANAX.

Model assumptions (documented per the paper's text):

* EYERISS baseline executes the transposed conv by sliding over the
  **zero-inserted** input: every (consequential or not) MAC occupies a PE
  cycle.  Zero-gating saves the *arithmetic* energy of inconsequential MACs
  (the paper: "EYERISS exploits data gating … but still wastes cycles")
  but register-file reads and the occupied cycle remain.
* GANAX executes only consequential MACs; PV load imbalance (different tap
  counts per phase) is computed exactly from the schedule; MIMD execution
  overlaps phase programs so the makespan is the balanced maximum over PVs.
* Horizontal partial-sum accumulation costs ``taps_y`` inter-PE hops per
  output-row wave (paper Fig. 4/5: 5 cycles → 2/3 cycles after
  reorganization).
* Memory traffic: the baseline streams the zero-inserted input through
  DRAM→global-buffer→RF (the zeros are materialized, as a conventional
  accelerator requires); GANAX streams the compact input.  Both stream
  weights once per output-tile wave and outputs once.
* Energy/bit numbers are Table II verbatim; 16-bit fixed-point datapath.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

from repro.core.scheduler import PhaseSchedule, make_schedule

__all__ = [
    "EnergyTable",
    "AcceleratorConfig",
    "ConvLayer",
    "LayerReport",
    "analyze_layer",
    "analyze_model",
]


@dataclasses.dataclass(frozen=True)
class EnergyTable:
    """Table II: energy per bit (pJ) in TSMC 45nm."""
    rf: float = 0.20           # register file access
    pe: float = 0.36           # 16-bit fixed-point MAC (incl. μindex gens)
    inter_pe: float = 0.40     # inter-PE communication
    gbuf: float = 1.20         # global buffer access
    dram: float = 15.00        # DDR4 access


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """§V architecture configuration (same array for EYERISS & GANAX)."""
    n_pvs: int = 16
    pes_per_pv: int = 16
    freq_hz: float = 500e6
    bits: int = 16

    @property
    def n_pes(self) -> int:
        return self.n_pvs * self.pes_per_pv


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One (transposed) convolution layer of a GAN.

    For ``transposed=True`` the geometry follows ``core.scheduler``;
    for plain convs ``strides`` is the downsampling stride.
    """
    name: str
    in_spatial: tuple[int, ...]
    kernel: tuple[int, ...]
    strides: tuple[int, ...]
    paddings: tuple[int, ...]
    cin: int
    cout: int
    transposed: bool = True
    batch: int = 1

    def schedule(self) -> PhaseSchedule:
        if not self.transposed:
            raise ValueError("schedule() only applies to transposed layers")
        return make_schedule(self.in_spatial, self.kernel, self.strides,
                             self.paddings)

    def conv_out_spatial(self) -> tuple[int, ...]:
        assert not self.transposed
        return tuple((n + 2 * p - k) // s + 1
                     for n, k, s, p in zip(self.in_spatial, self.kernel,
                                           self.strides, self.paddings))


@dataclasses.dataclass
class LayerReport:
    layer: ConvLayer
    total_macs: int                 # zero-inserted dataflow MACs
    consequential_macs: int
    cycles_baseline: float
    cycles_ganax: float
    energy_baseline_pj: dict[str, float]
    energy_ganax_pj: dict[str, float]
    util_baseline: float
    util_ganax: float

    @property
    def speedup(self) -> float:
        return self.cycles_baseline / self.cycles_ganax

    @property
    def energy_reduction(self) -> float:
        return (sum(self.energy_baseline_pj.values()) /
                sum(self.energy_ganax_pj.values()))

    @property
    def inconsequential_fraction(self) -> float:
        return 1.0 - self.consequential_macs / self.total_macs


def _pv_balance(sched: PhaseSchedule, acc: AcceleratorConfig) -> float:
    """Makespan inflation from PV load imbalance under MIMD scheduling.

    Rows (y-phase groups, longest first) are dealt to PVs in contiguous
    runs; returns max-PV-work / mean-PV-work (≥ 1).  Longest-first dealing
    keeps this near 1 for realistic sizes.
    """
    if sched.n_dims < 2:
        return 1.0
    y_dims = sched.dims[0]
    x_dims = sched.dims[1]
    per_row_work = {pd.phase: pd.n_taps * sum(xd.n_taps * xd.out_size
                                              for xd in x_dims)
                    for pd in y_dims}
    rows = []
    for pd in sorted(y_dims, key=lambda p: p.n_taps, reverse=True):
        rows.extend([per_row_work[pd.phase]] * pd.out_size)
    # LPT (longest processing time) assignment to PVs.
    loads = np.zeros(acc.n_pvs)
    for w in rows:
        loads[np.argmin(loads)] += w
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0


def analyze_layer(layer: ConvLayer,
                  acc: AcceleratorConfig = AcceleratorConfig(),
                  energy: EnergyTable = EnergyTable()) -> LayerReport:
    """Cycle + energy model for one layer under both dataflows."""
    b = layer.batch
    sched = layer.schedule() if layer.transposed else None
    if layer.transposed:
        total = sched.zero_inserted_macs(layer.cin, layer.cout, b)
        conseq = sched.consequential_macs(layer.cin, layer.cout, b)
        out_sizes = sched.out_sizes
    else:
        out_sizes = layer.conv_out_spatial()
        total = conseq = (int(np.prod(out_sizes)) *
                          int(np.prod(layer.kernel)) *
                          layer.cin * layer.cout * b)

    bits = acc.bits
    n_pes = acc.n_pes

    # ---- cycles ------------------------------------------------------------
    # Baseline: all MACs occupy cycles; EYERISS conv mapping utilization on a
    # dense conv is taken as 1.0 at this granularity (its conv dataflow is
    # the reference point the paper normalizes to).  Horizontal accumulation:
    # K_y hops per output-row wave.
    out_pix = int(np.prod(out_sizes)) * b
    waves = out_pix * layer.cout / n_pes
    ky = layer.kernel[0]
    cycles_base = total / n_pes + waves * ky

    # GANAX: consequential MACs, inflated by PV imbalance; accumulation
    # shortens to the per-phase tap count.
    imbalance = _pv_balance(sched, acc) if layer.transposed else 1.0
    if layer.transposed and sched.n_dims >= 1:
        y_dims = sched.dims[0]
        mean_taps_y = (sum(pd.n_taps * pd.out_size for pd in y_dims) /
                       max(1, sum(pd.out_size for pd in y_dims)))
    else:
        mean_taps_y = ky
    cycles_ganax = conseq / n_pes * imbalance + waves * mean_taps_y

    # ---- energy (pJ) --------------------------------------------------------
    # Per-MAC register file traffic: 2 operand reads + 1 partial-sum
    # read-modify-write ≈ 4 RF accesses of `bits` bits.
    rf_per_mac = 4 * bits * energy.rf
    pe_per_mac = bits * energy.pe
    hop = bits * energy.inter_pe

    # Data volumes (bits).
    in_bits_ganax = int(np.prod(layer.in_spatial)) * layer.cin * b * bits
    if layer.transposed:
        exp_pix = int(np.prod([s * (n - 1) + 1 + 2 * (k - 1 - p)
                               for n, s, k, p in zip(sched.in_sizes,
                                                     sched.strides,
                                                     sched.kernel,
                                                     sched.paddings)]))
        in_bits_base = exp_pix * layer.cin * b * bits      # zeros included
    else:
        in_bits_base = in_bits_ganax
    w_bits = int(np.prod(layer.kernel)) * layer.cin * layer.cout * bits
    out_bits = out_pix * layer.cout * bits

    # Global buffer: inputs re-read once per filter-row (row-stationary
    # vertical reuse covers the PE set, horizontal re-fetch per ky), weights
    # once per input-tile wave, outputs once.
    gb_base = (in_bits_base * ky + w_bits * max(1, waves / layer.cout)
               + out_bits) * energy.gbuf
    gb_ganax = (in_bits_ganax * mean_taps_y
                + w_bits * max(1, waves / layer.cout) + out_bits
                ) * energy.gbuf
    # DRAM: each tensor streamed once; the baseline streams the expanded
    # input (zeros materialized by the zero-insertion stage).
    dram_base = (in_bits_base + w_bits + out_bits) * energy.dram
    dram_ganax = (in_bits_ganax + w_bits + out_bits) * energy.dram
    # Inter-PE: one hop per MAC's partial-sum forward (horizontal
    # accumulation), charged per executed (cycle-occupying) MAC.
    inter_base = total * hop
    inter_ganax = conseq * hop
    # RF: baseline pays RF for every occupied cycle (zeros are fetched, then
    # gated); PE arithmetic energy only for consequential MACs (data gating).
    e_base = {
        "rf": total * rf_per_mac,
        "pe": conseq * pe_per_mac,
        "inter_pe": inter_base,
        "gbuf": gb_base,
        "dram": dram_base,
    }
    e_ganax = {
        "rf": conseq * rf_per_mac,
        "pe": conseq * pe_per_mac,
        "inter_pe": inter_ganax,
        "gbuf": gb_ganax,
        "dram": dram_ganax,
    }

    util_base = conseq / (cycles_base * n_pes)
    util_ganax = conseq / (cycles_ganax * n_pes)
    return LayerReport(layer=layer, total_macs=total,
                       consequential_macs=conseq,
                       cycles_baseline=cycles_base,
                       cycles_ganax=cycles_ganax,
                       energy_baseline_pj=e_base, energy_ganax_pj=e_ganax,
                       util_baseline=util_base, util_ganax=util_ganax)


@dataclasses.dataclass
class ModelReport:
    name: str
    generator: list[LayerReport]
    discriminator: list[LayerReport]

    def _agg(self, reports: list[LayerReport], field: str) -> float:
        return sum(getattr(r, field) for r in reports)

    @property
    def gen_speedup(self) -> float:
        return (self._agg(self.generator, "cycles_baseline") /
                self._agg(self.generator, "cycles_ganax"))

    @property
    def gen_energy_reduction(self) -> float:
        base = sum(sum(r.energy_baseline_pj.values())
                   for r in self.generator)
        gx = sum(sum(r.energy_ganax_pj.values()) for r in self.generator)
        return base / gx

    @property
    def gen_inconsequential_fraction(self) -> float:
        t = self._agg(self.generator, "total_macs")
        c = self._agg(self.generator, "consequential_macs")
        return 1.0 - c / t if t else 0.0

    def utilization(self, which: Literal["baseline", "ganax"]) -> float:
        field = f"util_{which}"
        # cycle-weighted mean over generator layers
        cfield = ("cycles_baseline" if which == "baseline"
                  else "cycles_ganax")
        cyc = self._agg(self.generator, cfield)
        return sum(getattr(r, field) * getattr(r, cfield)
                   for r in self.generator) / cyc if cyc else 0.0

    def energy_breakdown(self, which: Literal["baseline", "ganax"]) -> dict:
        key = ("energy_baseline_pj" if which == "baseline"
               else "energy_ganax_pj")
        out: dict[str, float] = {}
        for r in self.generator:
            for k, v in getattr(r, key).items():
                out[k] = out.get(k, 0.0) + v
        return out

    def runtime_split(self, which: Literal["baseline", "ganax"]) -> dict:
        cfield = ("cycles_baseline" if which == "baseline"
                  else "cycles_ganax")
        return {
            "generative": self._agg(self.generator, cfield),
            "discriminative": self._agg(self.discriminator, cfield),
        }


def analyze_model(name: str, gen_layers: list[ConvLayer],
                  disc_layers: list[ConvLayer],
                  acc: AcceleratorConfig = AcceleratorConfig(),
                  energy: EnergyTable = EnergyTable()) -> ModelReport:
    return ModelReport(
        name=name,
        generator=[analyze_layer(l, acc, energy) for l in gen_layers],
        discriminator=[analyze_layer(l, acc, energy) for l in disc_layers],
    )
