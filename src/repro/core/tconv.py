"""GANAX transposed convolution: polyphase ("row-reorganized") dataflow.

Two executable dataflows are provided for N-D transposed convolution
(channels-last layout, PyTorch ``ConvTranspose`` geometry semantics):

* :func:`tconv_zero_insert` — the conventional-accelerator baseline the
  paper compares against: materialize the zero-inserted input and run a
  dense convolution over it.  Every inserted zero costs a MAC, exactly like
  running the layer on an unmodified EYERISS.

* :func:`tconv_ganax` — the paper's dataflow: output/filter rows are
  regrouped by zero-pattern (= polyphase decomposition, see
  ``core/scheduler.py``) so only consequential MACs are executed, each phase
  being a dense, fully-regular convolution (SIMD inside a phase, distinct
  microprograms across phases = MIMD-SIMD).

Both produce bit-comparable results (up to dtype accumulation order) and
match ``jax.lax.conv_transpose``.
"""

from __future__ import annotations

import string
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.scheduler import PhaseSchedule, make_schedule

__all__ = [
    "tconv_zero_insert",
    "tconv_ganax",
    "tconv_output_shape",
    "interleave_phases",
]


def _spatial_dims(x: jax.Array) -> int:
    # (N, *spatial, C)
    return x.ndim - 2


def _dim_numbers(nd: int):
    """Channels-last dimension numbers for an nd-spatial conv."""
    letters = "".join(c for c in string.ascii_uppercase if c not in "NCIO")
    sp = letters[:nd]                         # e.g. "AB"
    lhs = "N" + sp + "C"
    rhs = sp + "IO"
    out = "N" + sp + "C"
    return lax.conv_dimension_numbers((0,) * (nd + 2), (0,) * (nd + 2),
                                      (lhs, rhs, out))


def accum_conv(lhs: jax.Array, rhs: jax.Array, *, window_strides,
               padding, dimension_numbers,
               preferred_element_type=jnp.float32) -> jax.Array:
    """``conv_general_dilated`` with true f32 accumulation at any
    storage precision.

    Low-precision (bf16/f16) operands are **upcast** to the accumulator
    dtype instead of passing narrow inputs with
    ``preferred_element_type`` — the result is bit-identical (narrow
    floats are exactly representable in f32), but unlike the
    mixed-dtype form the conv's *transpose* is defined, so native
    autodiff through the pure-JAX backends works at every storage
    precision.  f32 operands pass through untouched."""
    if preferred_element_type is not None:
        acc = jnp.dtype(preferred_element_type)
        lhs, rhs = lhs.astype(acc), rhs.astype(acc)
    return lax.conv_general_dilated(
        lhs, rhs, window_strides=window_strides, padding=padding,
        dimension_numbers=dimension_numbers,
        preferred_element_type=preferred_element_type)


def tconv_output_shape(x_shape: Sequence[int], w_shape: Sequence[int],
                       strides: Sequence[int], paddings: Sequence[int]
                       ) -> tuple[int, ...]:
    """(N, *spatial_out, C_out) for channels-last x and (K..., C_in, C_out) w."""
    nd = len(x_shape) - 2
    sched = make_schedule(x_shape[1:1 + nd], w_shape[:nd], strides, paddings)
    return (x_shape[0], *sched.out_sizes, w_shape[-1])


# ---------------------------------------------------------------------------
# Baseline dataflow: explicit zero insertion + dense convolution.
# ---------------------------------------------------------------------------

def zero_insert(x: jax.Array, strides: Sequence[int]) -> jax.Array:
    """Materialize the zero-expanded input (size ``s*(n-1)+1`` per dim)."""
    nd = _spatial_dims(x)
    strides = tuple(strides)
    out_sp = tuple(s * (n - 1) + 1
                   for n, s in zip(x.shape[1:1 + nd], strides))
    out = jnp.zeros((x.shape[0], *out_sp, x.shape[-1]), x.dtype)
    idx = (slice(None),) + tuple(slice(None, None, s) for s in strides) + (
        slice(None),)
    return out.at[idx].set(x)


def tconv_zero_insert(x: jax.Array, w: jax.Array, strides: Sequence[int],
                      paddings: Sequence[int],
                      preferred_element_type=jnp.float32) -> jax.Array:
    """Transposed conv via the conventional dataflow (baseline).

    Args:
      x: (N, *spatial, C_in), channels last.
      w: (*kernel, C_in, C_out).
      strides/paddings: per-spatial-dim ints, PyTorch ``ConvTranspose``
        semantics (padding is the forward-conv padding being transposed).
    """
    nd = _spatial_dims(x)
    strides = tuple(strides)
    paddings = tuple(paddings)
    kernel = w.shape[:nd]
    expanded = zero_insert(x, strides)
    # Correlate with the *flipped* kernel; pad by (k - 1 - p) per side.
    w_flipped = jnp.flip(w, axis=tuple(range(nd)))
    pads = tuple((k - 1 - p, k - 1 - p) for k, p in zip(kernel, paddings))
    return accum_conv(
        expanded, w_flipped, window_strides=(1,) * nd, padding=pads,
        dimension_numbers=_dim_numbers(nd),
        preferred_element_type=preferred_element_type,
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# GANAX dataflow: polyphase decomposition (output/filter row reorganization).
# ---------------------------------------------------------------------------

def _phase_conv(x: jax.Array, w: jax.Array, sched: PhaseSchedule,
                flat_phase: int, preferred_element_type) -> jax.Array:
    """Dense sub-convolution for one phase (one GANAX microprogram)."""
    nd = sched.n_dims
    pds = sched.phase_dims(flat_phase)
    # Gather this phase's kernel taps, reversed so XLA's correlation
    # (out[q] = Σ_t rhs[t]·lhs[q + t - pad_lo]) realizes
    # out[q] = Σ_t w[tap_t]·x[q + offset - t].
    w_sub = w
    for d, pd in enumerate(pds):
        taps = np.asarray(pd.taps[::-1], dtype=np.int32)
        w_sub = jnp.take(w_sub, taps, axis=d)
    pads = []
    for d, pd in enumerate(pds):
        n, m = pd.n_taps, pd.offset
        in_size = sched.in_sizes[d]
        pad_lo = n - 1 - m
        pad_hi = pd.out_size - in_size + m
        pads.append((pad_lo, pad_hi))
    return accum_conv(
        x, w_sub, window_strides=(1,) * nd, padding=tuple(pads),
        dimension_numbers=_dim_numbers(nd),
        preferred_element_type=preferred_element_type)


def interleave_phases(phase_outs: dict[tuple[int, ...], jax.Array],
                      sched: PhaseSchedule) -> jax.Array:
    """Scatter phase planes into the full output (the "row reorganization"
    permutation applied in reverse).

    Phase planes are zero-padded to a common ``ceil(out/s)`` grid, stacked,
    and interleaved with a reshape — a pure layout transformation (XLA
    transpose), no arithmetic.
    """
    nd = sched.n_dims
    strides = sched.strides
    q_sizes = tuple(-(-o // s) for o, s in zip(sched.out_sizes, strides))
    # Build an array indexed [phase_0, ..., phase_{nd-1}, N, q_0, ..., q_{nd-1}, C]
    first = next(iter(phase_outs.values()))
    n, c = first.shape[0], first.shape[-1]
    dtype = first.dtype
    planes = np.empty(tuple(strides), dtype=object)
    for phases, out in phase_outs.items():
        pad = [(0, 0)]
        for d in range(nd):
            pad.append((0, q_sizes[d] - out.shape[1 + d]))
        pad.append((0, 0))
        planes[phases] = jnp.pad(out, pad)
    stacked = jnp.stack([planes[idx] for idx in np.ndindex(*strides)])
    stacked = stacked.reshape(tuple(strides) + (n, *q_sizes, c))
    # target order: (N, q_0, phase_0, q_1, phase_1, ..., C)
    perm = [nd]  # N
    for d in range(nd):
        perm.extend([nd + 1 + d, d])
    perm.append(2 * nd + 1)  # C
    inter = jnp.transpose(stacked, perm)
    full = inter.reshape((n,) + tuple(q * s for q, s in zip(q_sizes, strides))
                         + (c,))
    slc = (slice(None),) + tuple(slice(0, o) for o in sched.out_sizes) + (
        slice(None),)
    return full[slc]


def tconv_ganax(x: jax.Array, w: jax.Array, strides: Sequence[int],
                paddings: Sequence[int],
                preferred_element_type=jnp.float32,
                schedule: PhaseSchedule | None = None) -> jax.Array:
    """Transposed conv via the GANAX dataflow (pure-JAX reference).

    Executes only consequential MACs: one dense sub-convolution per output
    phase, then a zero-arithmetic interleave.  Stride 1 degenerates to a
    single plain convolution (paper's SIMD mode / discriminator path).
    """
    nd = _spatial_dims(x)
    strides = tuple(strides)
    paddings = tuple(paddings)
    sched = schedule or make_schedule(x.shape[1:1 + nd], w.shape[:nd],
                                      strides, paddings)
    outs = {}
    for flat in sched.phase_order:  # longest-microprogram-first
        phases = sched.phase_tuple(flat)
        pds = sched.phase_dims(flat)
        if any(pd.n_taps == 0 for pd in pds):
            # no consequential taps: this phase's outputs are all zero
            # (possible when kernel < stride)
            outs[phases] = jnp.zeros(
                (x.shape[0],) + tuple(pd.out_size for pd in pds)
                + (w.shape[-1],), x.dtype)
            continue
        outs[phases] = _phase_conv(x, w, sched, flat,
                                   preferred_element_type).astype(x.dtype)
    if sched.n_phases == 1:
        return outs[(0,) * nd]
    return interleave_phases(outs, sched)
