"""Declarative, ahead-of-time-resolved GAN execution specs.

GANAX's core move is ahead-of-time specialization: a deconv layer's
access patterns are static, so the accelerator compiles one microprogram
per output-row pattern *once* and then executes flat-out.  This module
lifts that principle to the model level.  :meth:`ProgramSpec.build`
walks a :class:`~repro.models.gan.GanConfig`'s layers **once** and
freezes a tuple of :class:`LayerExec` records — op kind, geometry,
fused epilogue, the resolved concrete backend + Pallas block shapes,
and the resolution's provenance (``pinned`` / ``tuned`` /
``heuristic``).  Nothing is re-resolved per call: the runtime
(:class:`repro.program.Program`) replays the frozen records.

Specs round-trip through JSON (:meth:`ProgramSpec.to_json` /
:meth:`ProgramSpec.from_json`), so a program tuned on a measurement box
can be exported and loaded on a serving box with **zero** planner
measurements — the serving process never needs a planner at all.
``from_json`` validates hard (version, backends, ranks, block shapes):
a stale or corrupt file raises ``ValueError`` so loaders can fall back
to fresh resolution (see :func:`repro.program.load_or_build`).
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax

from repro import obs as _obs
from repro.core.dataflow import (SHARDINGS, DataflowPolicy, Epilogue,
                                 available_backends, backend_supports,
                                 blocks_valid, resolve_execution)

__all__ = ["LayerExec", "ProgramSpec", "PROGRAM_FORMAT_VERSION",
           "SUPPORTED_PROGRAM_VERSIONS", "ROLES"]

# Version 2 added the mesh/sharding fields; version 3 added the
# optional embedded int8 weight payload (``quantized_params``, written
# by :func:`repro.quant.weights.quantize_program`).  Older documents
# still load: v1 defaults to single-device, v1/v2 to float32 storage
# with no quantized payload — see ``from_json``.
PROGRAM_FORMAT_VERSION = 3
SUPPORTED_PROGRAM_VERSIONS = (1, 2, 3)

ROLES = ("generator", "discriminator")

# ``build(mesh=...)``'s "not passed" sentinel: None is a meaningful
# value (force single-device even if cfg carries a mesh).
_UNSET = object()


@dataclasses.dataclass(frozen=True)
class LayerExec:
    """One frozen layer execution record of a compiled GAN program.

    The geometry fields mirror :class:`~repro.core.analytical.ConvLayer`;
    ``w_param`` / ``b_param`` name the entries of the params dict the
    runtime reads; ``backend`` / ``blocks`` are the *concrete* resolved
    execution path (never ``"auto"`` or a preference form); ``source``
    records where that resolution came from (``pinned`` / ``tuned`` /
    ``heuristic``) and ``measured_us`` the winning plan's wall-clock
    when it was tuned.

    ``sharding`` is the layer's frozen mesh layout (one of
    :data:`repro.core.dataflow.SHARDINGS`): ``"data"`` = batch split
    over the ``data`` axis with replicated weights, ``"cout"`` =
    weights additionally sharded on Cout over the ``model`` axis (the
    local output is all-gathered back to full Cout).  Meaningful only
    when the owning :class:`ProgramSpec` carries a mesh; always
    ``"data"`` otherwise.
    """

    name: str
    kind: str                       # "tconv" | "conv"
    in_spatial: tuple[int, ...]
    kernel: tuple[int, ...]
    strides: tuple[int, ...]
    paddings: tuple[int, ...]
    cin: int
    cout: int
    w_param: str
    b_param: str | None
    bias: bool
    activation: str
    leaky_slope: float
    backend: str
    blocks: tuple[int, ...] | None
    source: str                     # "pinned" | "tuned" | "heuristic"
    measured_us: float | None = None
    sharding: str = "data"          # "data" | "cout"

    def __post_init__(self):
        if self.kind not in ("tconv", "conv"):
            raise ValueError(f"unknown layer kind {self.kind!r}")
        if self.source not in ("pinned", "tuned", "heuristic"):
            raise ValueError(f"unknown resolution source {self.source!r}")
        if self.sharding not in SHARDINGS:
            raise ValueError(f"unknown layer sharding "
                             f"{self.sharding!r}; one of {SHARDINGS}")
        # constructing the epilogue validates activation/leaky_slope —
        # a corrupt program file must fail here, not at first trace
        Epilogue(bias=self.bias, activation=self.activation,
                 leaky_slope=self.leaky_slope)
        if self.bias and self.b_param is None:
            raise ValueError(f"layer {self.name!r} has bias=True but "
                             f"no b_param")

    @property
    def nd(self) -> int:
        return len(self.in_spatial)

    @property
    def epilogue(self) -> Epilogue:
        return Epilogue(bias=self.bias, activation=self.activation,
                        leaky_slope=self.leaky_slope)

    def plan_key(self, batch: int, dtype: str, platform: str):
        """The autotuner :class:`~repro.tune.PlanKey` of this layer —
        the single source the tuner's zoo entry points key plans on."""
        from repro.tune.planner import PlanKey
        return PlanKey(kind=self.kind, batch=int(batch),
                       in_spatial=self.in_spatial, kernel=self.kernel,
                       strides=self.strides, paddings=self.paddings,
                       cin=self.cin, cout=self.cout, dtype=dtype,
                       platform=platform, **self.epilogue.key_fields())

    def geometry_signature(self) -> tuple:
        """The layer's workload identity (everything but the resolved
        execution) — what a program file must match to serve a config."""
        return (self.name, self.kind, self.in_spatial, self.kernel,
                self.strides, self.paddings, self.cin, self.cout,
                self.bias, self.activation, self.leaky_slope)

    def describe(self) -> str:
        sp = "x".join(map(str, self.in_spatial))
        k = "x".join(map(str, self.kernel))
        s = "x".join(map(str, self.strides))
        exec_ = self.backend
        if self.blocks:
            exec_ += f"[{'x'.join(map(str, self.blocks))}]"
        us = "" if self.measured_us is None \
            else f"  {self.measured_us:.0f}us"
        shard = "" if self.sharding == "data" else f"  @{self.sharding}"
        return (f"{self.name}: {self.kind} {sp} k{k} s{s} "
                f"{self.cin}->{self.cout}  ep[{self.epilogue.describe()}]"
                f"  -> {exec_}{shard}  ({self.source}{us})")

    def to_json(self) -> dict:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)}
        d["blocks"] = list(self.blocks) if self.blocks else None
        for f in ("in_spatial", "kernel", "strides", "paddings"):
            d[f] = list(d[f])
        return d

    @classmethod
    def from_json(cls, d: dict) -> "LayerExec":
        names = {f.name for f in dataclasses.fields(cls)}
        # measured_us and sharding are optional on input: version-1
        # documents predate sharding and default to "data"
        if not (names - {"measured_us", "sharding"} <= set(d) <= names):
            raise ValueError(f"bad layer fields: {sorted(d)}")
        d = dict(d)
        for f in ("in_spatial", "kernel", "strides", "paddings"):
            d[f] = tuple(int(v) for v in d[f])
        for f in ("cin", "cout"):
            d[f] = int(d[f])
        if d.get("blocks") is not None:
            d["blocks"] = tuple(int(v) for v in d["blocks"])
        le = cls(**d)
        # the epilogue/kind/source checks ran in __post_init__; now the
        # executable part: the backend must exist, run this rank, and
        # (for the kernel backends) accept the recorded tile shapes
        if le.backend not in available_backends():
            raise ValueError(f"unknown backend {le.backend!r} in layer "
                             f"{le.name!r}")
        if not backend_supports(le.backend, le.nd):
            raise ValueError(f"backend {le.backend!r} does not support "
                             f"{le.nd}-D layer {le.name!r}")
        if le.blocks is not None:
            if not le.backend.startswith("pallas"):
                raise ValueError(f"layer {le.name!r} carries blocks on "
                                 f"non-kernel backend {le.backend!r}")
            if not blocks_valid(le.kind, le.in_spatial, le.kernel,
                                le.strides, le.paddings, le.cin, le.cout,
                                le.blocks):
                raise ValueError(f"stale blocks {le.blocks} for layer "
                                 f"{le.name!r}")
        return le


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """A frozen, fully resolved execution plan for one GAN network.

    ``batch`` is the *planning* batch — the batch size the autotuner
    plans were keyed on; the runtime accepts any batch (a new batch
    shape is just a retrace of the same frozen records).  ``platform``
    records where the spec was resolved (provenance — a pinned program
    executes its recorded backends wherever it loads).
    ``requested_backend`` preserves the policy form the spec was built
    from (``None`` = heuristic), purely for display.

    ``mesh`` freezes the device layout the program was resolved for:
    ``(data, model)`` device counts over the ``("data", "model")`` axes
    of :func:`repro.launch.mesh.make_local_mesh`, or ``None`` for a
    single-device program.  The mesh is a property of the *program*,
    not the call site — an exported meshed spec serves identically on
    any box with enough devices, and degrades (with a warning) to
    single-device where there aren't.  It is provenance-like but
    executable, so it is excluded from :meth:`geometry_signature`: a
    meshed program still serves the same workload.

    ``dtype`` is the **storage** precision (one of
    :data:`repro.quant.SUPPORTED_STORAGE_DTYPES`; accumulation is
    always f32 — see :mod:`repro.quant`).  Unlike the mesh it *is*
    part of the geometry signature: a bf16 program computes a
    different function than the f32 one, so a file at the wrong
    precision must not serve a config.  ``quantized_params`` is the
    optional embedded int8 weight payload of an exported quantized
    program (the v3 JSON form; see
    :func:`repro.quant.weights.quantize_program`) — ``None`` for
    ordinary specs, whose params live with the caller.
    """

    model: str
    role: str                       # "generator" | "discriminator"
    batch: int
    z_dim: int | None               # generator programs only
    channel_scale: float
    dtype: str
    platform: str
    requested_backend: str | None
    layers: tuple[LayerExec, ...]
    mesh: tuple[int, int] | None = None
    quantized_params: dict | None = None

    def __post_init__(self):
        from repro.quant.precision import canonical_dtype
        if self.role not in ROLES:
            raise ValueError(f"unknown program role {self.role!r}; "
                             f"one of {ROLES}")
        # canonicalize ("bf16" → "bfloat16") and reject non-storage
        # dtypes before they leak into plan keys or serialized files
        object.__setattr__(self, "dtype", canonical_dtype(self.dtype))
        if not self.layers:
            raise ValueError("a program needs at least one layer")
        if self.mesh is not None:
            if (len(self.mesh) != 2
                    or any(not isinstance(v, int) or v < 1
                           for v in self.mesh)):
                raise ValueError(f"mesh must be two positive ints "
                                 f"(data, model), got {self.mesh!r}")
        if self.quantized_params is not None:
            # hard-validates scheme/records/payload sizes — a corrupt
            # quantized file must raise at load (where loaders degrade
            # to fresh resolution), never at first trace
            from repro.quant.weights import validate_quantized
            validate_quantized(self.quantized_params)
        model_dim = self.mesh[1] if self.mesh else 1
        for le in self.layers:
            if le.sharding == "cout":
                if model_dim <= 1:
                    raise ValueError(
                        f"layer {le.name!r} is Cout-sharded but the "
                        f"program mesh {self.mesh!r} has no model axis")
                if le.cout % model_dim:
                    raise ValueError(
                        f"layer {le.name!r} cout={le.cout} does not "
                        f"divide over model axis of {model_dim}")

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, cfg, batch: int, role: str = "generator", *,
              policy: DataflowPolicy | None = None, planner=None,
              measure: bool = False, dtype: str | None = None,
              mesh=_UNSET, cout_shard_min_bytes: int | None = None
              ) -> "ProgramSpec":
        """Walk ``cfg``'s layers once and freeze every resolution.

        ``policy`` defaults to ``cfg.policy``.  With
        ``backend="auto"`` each layer consults the autotuning planner
        (``planner`` or the process-wide one); ``measure=True``
        additionally tunes plan misses — the ahead-of-time analogue of
        the old per-call warmup, and the only place measurement belongs.

        ``dtype`` is the storage precision (default: ``cfg.dtype``,
        float32 for configs without the field).  It enters every
        layer's plan key — each precision is its own tuning workload —
        and the sharding footprint heuristic (half-width weights clear
        the Cout threshold half as often).

        ``mesh`` freezes a ``(data, model)`` device layout into the
        spec (default: ``cfg.mesh``; pass ``None`` explicitly to force
        single-device).  Each layer's sharding is chosen by the
        footprint heuristic in
        :func:`repro.core.dataflow.choose_layer_sharding`
        (``cout_shard_min_bytes`` overrides its threshold — tests use
        ``0`` to force Cout sharding on small configs).
        """
        from repro.models.gan import (discriminator_epilogues,
                                      generator_epilogues)
        from repro.quant.precision import canonical_dtype
        if role not in ROLES:
            raise ValueError(f"unknown program role {role!r}; "
                             f"one of {ROLES}")
        policy = policy or cfg.policy
        dtype = canonical_dtype(
            getattr(cfg, "dtype", "float32") if dtype is None else dtype)
        if mesh is _UNSET:
            mesh = getattr(cfg, "mesh", None)
        if mesh is not None:
            mesh = (int(mesh[0]), int(mesh[1]))
        mesh_model = mesh[1] if mesh else 1
        g_layers, d_layers = cfg.layers
        if role == "generator":
            layers, prefix = g_layers, "t"
            epilogues = generator_epilogues(g_layers)
        else:
            layers, prefix = d_layers, "c"
            epilogues = discriminator_epilogues(d_layers)
        records = []
        with _obs.trace("program.build", model=cfg.name, role=role,
                        batch=int(batch), measure=bool(measure),
                        layers=len(layers)):
            for i, (l, ep) in enumerate(zip(layers, epilogues)):
                kind = "tconv" if l.transposed else "conv"
                res = resolve_execution(
                    policy, kind, l.in_spatial, l.kernel, l.strides,
                    l.paddings, l.cin, l.cout, batch=batch, dtype=dtype,
                    epilogue=ep, planner=planner, measure=measure,
                    mesh_model=mesh_model,
                    cout_shard_min_bytes=cout_shard_min_bytes)
                records.append(LayerExec(
                    name=l.name, kind=kind,
                    in_spatial=tuple(l.in_spatial),
                    kernel=tuple(l.kernel),
                    strides=tuple(l.strides), paddings=tuple(l.paddings),
                    cin=int(l.cin), cout=int(l.cout),
                    w_param=f"{prefix}{i}_w",
                    b_param=f"{prefix}{i}_b" if ep.bias else None,
                    bias=ep.bias, activation=ep.activation,
                    leaky_slope=ep.leaky_slope,
                    backend=res.backend, blocks=res.blocks,
                    source=res.source, measured_us=res.measured_us,
                    sharding=res.sharding))
        _obs.counter("program.builds").inc()
        return cls(model=cfg.name, role=role, batch=int(batch),
                   z_dim=int(cfg.z_dim) if role == "generator" else None,
                   channel_scale=float(cfg.channel_scale), dtype=dtype,
                   platform=jax.default_backend(),
                   requested_backend=policy.backend,
                   layers=tuple(records), mesh=mesh)

    # -- queries ------------------------------------------------------------
    def plan_keys(self) -> list[tuple[str, object]]:
        """(layer name, :class:`~repro.tune.PlanKey`) per layer — what
        the tuner's zoo entry points iterate instead of re-deriving
        layer groups themselves."""
        return [(le.name, le.plan_key(self.batch, self.dtype,
                                      self.platform))
                for le in self.layers]

    def geometry_signature(self) -> tuple:
        """The whole network's workload identity: a loaded spec whose
        signature differs from a freshly built one is stale (topology,
        scaling, or **storage-precision** drift) and must not serve.
        The storage dtype is part of the identity — a bf16 program
        computes a different function than the f32 one — while the
        mesh and the quantized payload are not (they change where/how
        the same function runs, not what it computes... up to the
        checked-in quantization tolerance)."""
        return (self.model, self.role, self.z_dim, self.dtype, tuple(
            le.geometry_signature() for le in self.layers))

    def summary(self) -> str:
        """One-line resolution summary (the repr-sized form of
        :meth:`describe`)."""
        if self.requested_backend == "auto":
            per_layer = ", ".join(
                f"{le.name}->{le.backend}"
                + (f"[{'x'.join(map(str, le.blocks))}]" if le.blocks
                   else "")
                for le in self.layers)
            return f"auto({per_layer})"
        backends = sorted({le.backend for le in self.layers})
        return backends[0] if len(backends) == 1 \
            else f"mixed({', '.join(backends)})"

    def describe(self) -> str:
        """The human-readable program listing: header plus one line per
        frozen layer record."""
        mesh = "" if self.mesh is None else \
            f"mesh={self.mesh[0]}x{self.mesh[1]}  "
        quant = "" if self.quantized_params is None else "quant=int8  "
        head = (f"program {self.model}/{self.role}  "
                f"batch={self.batch}  dtype={self.dtype}  {quant}"
                f"platform={self.platform}  {mesh}"
                f"policy={self.requested_backend or 'heuristic'}  "
                f"({len(self.layers)} layers)")
        return "\n".join([head] + [f"  {le.describe()}"
                                   for le in self.layers])

    # -- persistence --------------------------------------------------------
    def to_json(self) -> dict:
        doc = {
            "version": PROGRAM_FORMAT_VERSION,
            "model": self.model, "role": self.role, "batch": self.batch,
            "z_dim": self.z_dim, "channel_scale": self.channel_scale,
            "dtype": self.dtype, "platform": self.platform,
            "requested_backend": self.requested_backend,
            "layers": [le.to_json() for le in self.layers],
            "mesh": list(self.mesh) if self.mesh else None,
        }
        if self.quantized_params is not None:
            doc["quantized_params"] = self.quantized_params
        return doc

    @classmethod
    def from_json(cls, doc: dict) -> "ProgramSpec":
        if not isinstance(doc, dict):
            raise ValueError(f"program doc must be a dict, got "
                             f"{type(doc).__name__}")
        version = doc.get("version")
        if version not in SUPPORTED_PROGRAM_VERSIONS:
            raise ValueError(f"unsupported program version "
                             f"{version!r} "
                             f"(want one of {SUPPORTED_PROGRAM_VERSIONS})")
        layers = doc.get("layers")
        if not isinstance(layers, list) or not layers:
            raise ValueError("program doc has no 'layers' list")
        # version-gated defaults: v1 documents predate the mesh fields
        # and mean a single-device program; v1/v2 predate the storage-
        # precision and quantization fields and mean plain float32
        mesh = doc.get("mesh") if version >= 2 else None
        if mesh is not None:
            if not isinstance(mesh, (list, tuple)) or len(mesh) != 2:
                raise ValueError(f"program mesh must be [data, model], "
                                 f"got {mesh!r}")
            mesh = (int(mesh[0]), int(mesh[1]))
        dtype = str(doc.get("dtype", "float32")) if version >= 3 \
            else "float32"
        quantized = doc.get("quantized_params") if version >= 3 else None
        z_dim = doc.get("z_dim")
        return cls(model=str(doc["model"]), role=str(doc["role"]),
                   batch=int(doc["batch"]),
                   z_dim=None if z_dim is None else int(z_dim),
                   channel_scale=float(doc.get("channel_scale", 1.0)),
                   dtype=dtype,
                   platform=str(doc.get("platform", "cpu")),
                   requested_backend=doc.get("requested_backend"),
                   layers=tuple(LayerExec.from_json(d) for d in layers),
                   mesh=mesh, quantized_params=quantized)

    def save(self, path) -> None:
        """Atomically write the spec's JSON document to ``path``."""
        path = os.fspath(path)
        tmp = f"{path}.tmp.{os.getpid()}"
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path) -> "ProgramSpec":
        """Read + validate a spec JSON file (raises on corrupt/stale —
        use :func:`repro.program.load_or_build` for the degrading
        form)."""
        with open(path) as f:
            return cls.from_json(json.load(f))
