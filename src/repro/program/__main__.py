"""``python -m repro.program`` — build, describe, export, load, and
account ahead-of-time compiled GAN programs.

Typical use::

    PYTHONPATH=src python -m repro.program dcgan
    PYTHONPATH=src python -m repro.program dcgan --backend auto \
        --plans plans.json --export dcgan-program.json
    PYTHONPATH=src python -m repro.program dcgan --load dcgan-program.json
    PYTHONPATH=src python -m repro.program dcgan --backend auto --stats
    PYTHONPATH=src python -m repro.program dcgan --dtype bf16 \
        --quantize int8 --export dcgan-int8.json

The first form is the CI smoke: resolving the whole spec touches no
arrays and runs no jit — a broken resolution path fails fast and cheap.
The last prints the resolution-counter deltas of the build (plan-cache
hits/misses, pinned/tuned/heuristic provenance, degradations) from the
``repro.obs`` metrics registry — the quickest answer to "did my plan
file actually get used".
"""

from __future__ import annotations

import argparse
import sys

from repro.configs.gans import GAN_MODELS
from repro.core.dataflow import DataflowPolicy, available_backends


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.program",
        description="Build, describe, export/load, and (--stats) "
                    "account the resolution of an ahead-of-time "
                    "compiled GAN program (the supported execution "
                    "API).")
    ap.add_argument("model", choices=sorted(GAN_MODELS))
    ap.add_argument("--role", default="both",
                    choices=("generator", "discriminator", "both"))
    ap.add_argument("--batch", type=int, default=8,
                    help="planning batch (plan keys; apply() accepts "
                         "any batch)")
    ap.add_argument("--channel-scale", type=float, default=1.0)
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="freeze a (data, model) device mesh into the "
                         "spec, e.g. 4x2 (per-layer sharding chosen by "
                         "the footprint heuristic; the exported file "
                         "degrades to single-device on boxes without "
                         "the devices)")
    ap.add_argument("--dtype", default=None,
                    help="storage precision frozen into the spec: "
                         "float32 (default), bfloat16, or float16 "
                         "(aliases f32/bf16/f16 accepted); "
                         "accumulation is always f32")
    ap.add_argument("--quantize", default=None, choices=("int8",),
                    help="with --export: embed per-channel symmetric "
                         "int8 weights (+ f32 scales) in the program "
                         "file, from a seed-0 init of the model (the "
                         "export-transform demo flow; real deployments "
                         "call repro.quant.quantize_program on trained "
                         "params)")
    ap.add_argument("--backend", default=None,
                    help="policy backend (a registered name, 'pallas', "
                         f"or 'auto'; registered: "
                         f"{', '.join(available_backends())}; default: "
                         "heuristic)")
    ap.add_argument("--plans", default=None, metavar="PATH",
                    help="autotuner plan file consulted by "
                         "--backend auto")
    ap.add_argument("--measure", action="store_true",
                    help="with --backend auto: tune plan misses while "
                         "building (the tuned-program export flow; "
                         "without it, resolution is lookup-only and a "
                         "cold planner exports heuristic layers)")
    ap.add_argument("--export", default=None, metavar="PATH",
                    help="write the (first-role) spec JSON here")
    ap.add_argument("--load", default=None, metavar="PATH",
                    help="load a program file instead of resolving "
                         "(falls back to fresh resolution when "
                         "corrupt/stale)")
    ap.add_argument("--stats", action="store_true",
                    help="after describing, print the resolution "
                         "metrics this invocation produced (plan-cache "
                         "hits/misses, provenance breakdown, "
                         "degradations)")
    args = ap.parse_args(argv)

    from repro import obs
    from repro.models.gan import GanConfig
    from repro.program import Program, ProgramSpec, load_or_build

    counters0 = dict(obs.snapshot()["counters"]) if args.stats else {}

    planner = None
    if args.plans:
        from repro.tune import Planner
        planner = Planner(args.plans)
        if planner.load_error:
            print(f"warning: plan file ignored ({planner.load_error})")
    policy = DataflowPolicy(backend=args.backend) if args.backend \
        else None
    mesh = None
    if args.mesh:
        try:
            data, model = args.mesh.lower().split("x")
            mesh = (int(data), int(model))
        except ValueError:
            ap.error(f"--mesh wants DATAxMODEL (e.g. 4x2), "
                     f"got {args.mesh!r}")
    try:
        cfg = GanConfig(name=args.model,
                        channel_scale=args.channel_scale,
                        backend=args.backend, mesh=mesh,
                        dtype=args.dtype or "float32")
    except ValueError as e:
        ap.error(str(e))
    if args.quantize and not args.export:
        ap.error("--quantize only makes sense with --export")
    roles = (args.role,) if args.role != "both" \
        else ("generator", "discriminator")
    if args.load and args.role == "both":
        # a program file freezes one network; describe that one (a
        # corrupt file keeps the generator default and falls back)
        try:
            roles = (ProgramSpec.load(args.load).role,)
        except Exception:
            roles = ("generator",)

    exported = False
    for role in roles:
        if args.load:
            prog, loaded = load_or_build(
                args.load, cfg, args.batch, role, policy=policy,
                planner=planner, measure=args.measure)
            if not loaded:
                print(f"note: {args.load} unusable for "
                      f"{args.model}/{role}; rebuilt from config")
            spec = prog.spec
        else:
            spec = ProgramSpec.build(cfg, args.batch, role,
                                     policy=policy, planner=planner,
                                     measure=args.measure)
        print(spec.describe())
        if args.export and not exported:
            if args.quantize:
                import jax

                from repro.models.gan import init_gan
                from repro.quant import quantize_program
                g_params, d_params = init_gan(cfg, jax.random.PRNGKey(0))
                params = g_params if spec.role == "generator" \
                    else d_params
                spec = quantize_program(spec, params)
            spec.save(args.export)
            print(f"wrote {args.export}"
                  + (" (int8 weights embedded)" if args.quantize
                     else ""))
            exported = True
        if role != roles[-1]:
            print()
    # a loadable spec is also buildable into a runtime object; keep the
    # smoke honest by exercising the wrap (no trace, no arrays)
    Program(spec)
    if args.stats:
        counters = obs.snapshot()["counters"]
        deltas = {k: v - counters0.get(k, 0)
                  for k, v in sorted(counters.items())
                  if v - counters0.get(k, 0)
                  and (k.startswith("dataflow.resolve")
                       or k.startswith("program."))}
        print("\nresolution stats:")
        for name, v in deltas.items():
            print(f"  {name:36s} {v}")
        if not deltas:
            print("  (none)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
