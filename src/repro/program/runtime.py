"""The executable form of a :class:`~repro.program.ProgramSpec`.

A :class:`Program` binds a frozen spec to **one** jitted callable:
``program.apply(params, x)`` traces the whole network once (per input
shape/dtype) and replays the compiled executable afterwards — no
per-call config → policy → plan threading anywhere on the hot path.
The per-layer policies are concrete pinned backends (the spec resolved
them ahead of time), so tracing never touches the autotuning planner:
an exported program serves on a planner-less process with zero
measurements.
"""

from __future__ import annotations

import logging
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs as _obs
from repro.compat import shard_map as _shard_map
from repro.core.dataflow import DataflowPolicy
from repro.core.dataflow import conv as df_conv
from repro.core.dataflow import tconv as df_tconv
from repro.launch.mesh import make_local_mesh
from repro.program.spec import _UNSET as _SPEC_UNSET
from repro.program.spec import ProgramSpec

__all__ = ["Program", "build_bucket_programs", "load_or_build"]

log = logging.getLogger(__name__)


class Program:
    """One GAN network as an ahead-of-time compiled executable.

    ``forward`` is the traceable (unjitted) computation — use it inside
    a larger ``jit`` (a train step, a loss);  ``apply`` is the jitted
    standalone entry point serving uses.  ``traces`` counts actual
    traces of ``apply`` — the executable-reuse contract is testable:
    repeated same-shape calls keep it at 1.

    A spec with a frozen ``mesh`` makes the program **sharded**:
    ``forward``/``apply`` wrap the layer replay in one
    ``shard_map`` over a ``("data", "model")`` mesh — the batch splits
    over ``data`` (weights replicated: the shard_map transpose psums
    their cotangents, so data-parallel gradient reduction is automatic
    when the forward is differentiated), and ``"cout"``-sharded layers
    run on a local Cout shard of their weights followed by a tiled
    ``all_gather``.  When the local process has fewer devices than the
    spec's mesh needs, the program **degrades to single-device with a
    warning** (``self.mesh is None``, ``program.mesh_degraded``
    counter) — the exported file serves anywhere, just unsharded.
    """

    def __init__(self, spec: ProgramSpec, *, differentiable: bool = True):
        from repro.quant.precision import storage_dtype
        self.spec = spec
        self.differentiable = bool(differentiable)
        self._policies = tuple(
            DataflowPolicy(backend=le.backend,
                           differentiable=self.differentiable)
            for le in spec.layers)
        # the storage precision every activation/weight is cast to at
        # use (f32 = no-op); params may stay f32 in the caller's
        # optimizer — the cast is inside the trace, so gradients flow
        # back to the parameter dtype (mixed-precision training)
        self._storage = storage_dtype(spec.dtype)
        self._dequantized = None
        self.traces = 0
        self.mesh = None
        if spec.mesh is not None:
            need = spec.mesh[0] * spec.mesh[1]
            have = len(jax.devices())
            if need > have:
                warnings.warn(
                    f"program {spec.model}/{spec.role} wants a "
                    f"{spec.mesh[0]}x{spec.mesh[1]} mesh ({need} "
                    f"devices) but only {have} available; degrading "
                    f"to single-device execution", RuntimeWarning,
                    stacklevel=2)
                _obs.counter("program.mesh_degraded").inc()
            else:
                self.mesh = make_local_mesh(data=spec.mesh[0],
                                            model=spec.mesh[1])
                _obs.counter("program.sharded").inc()
        # parameter layouts for the sharded path: Cout-sharded layers
        # split their weight's last (Cout) axis and bias over "model";
        # everything else (incl. the generator projection) replicates
        self._param_pspecs = {}
        if self.mesh is not None:
            for le in spec.layers:
                if le.sharding != "cout":
                    continue
                self._param_pspecs[le.w_param] = \
                    P(*((None,) * (le.nd + 1) + ("model",)))
                if le.bias:
                    self._param_pspecs[le.b_param] = P("model")

        def _traced(params, x):
            # Runs once per input shape (trace time, not per call) —
            # cheap enough to always count, visible in ``--stats``.
            self.traces += 1
            _obs.counter("program.traces").inc()
            if self.traces > 1:
                _obs.counter("program.retraces").inc()
            return self.forward(params, x)
        self._apply = jax.jit(_traced)

    @classmethod
    def build(cls, cfg, batch: int, role: str = "generator", *,
              policy: DataflowPolicy | None = None, planner=None,
              measure: bool = False, dtype: str | None = None,
              differentiable: bool = True, mesh=_SPEC_UNSET,
              cout_shard_min_bytes: int | None = None) -> "Program":
        """:meth:`ProgramSpec.build` + wrap — the one-call form."""
        spec = ProgramSpec.build(cfg, batch, role, policy=policy,
                                 planner=planner, measure=measure,
                                 dtype=dtype, mesh=mesh,
                                 cout_shard_min_bytes=cout_shard_min_bytes)
        return cls(spec, differentiable=differentiable)

    # -- embedded (quantized) parameters ------------------------------------
    @property
    def quantized(self) -> bool:
        """True when the spec carries an embedded int8 weight payload
        (an exported quantized program)."""
        return self.spec.quantized_params is not None

    @property
    def params(self):
        """The spec's embedded int8 payload dequantized into the
        storage dtype (weights → ``spec.dtype``, biases → f32),
        materialized once per Program and deterministic across loads —
        the tree callers hand straight to :meth:`apply` /
        ``GanServer``.  ``None`` for ordinary programs, whose params
        live with the caller."""
        if self.spec.quantized_params is None:
            return None
        if self._dequantized is None:
            from repro.quant.weights import dequantize_params
            self._dequantized = dequantize_params(
                self.spec.quantized_params, self.spec.dtype)
        return self._dequantized

    # -- sharding queries ---------------------------------------------------
    @property
    def input_sharding(self) -> NamedSharding | None:
        """How callers should place input batches: batch dim split over
        the ``data`` axis (``None`` for unsharded / degraded programs —
        callers skip the ``device_put``)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P("data"))

    @property
    def device_count(self) -> int:
        """Devices this program actually executes on (1 when unsharded
        or degraded)."""
        return self.mesh.devices.size if self.mesh is not None else 1

    @property
    def mesh_str(self) -> str:
        """``"4x2"``-style label of the *active* mesh (``"1"`` when
        unsharded or degraded) — the span-attr form."""
        if self.mesh is None:
            return "1"
        return f"{self.spec.mesh[0]}x{self.spec.mesh[1]}"

    # -- execution ----------------------------------------------------------
    def forward(self, params, x):
        """Replay the frozen layer records (traceable; donate to ``jit``
        via :meth:`apply` or embed in a caller's trace).  On a sharded
        program this *is* the ``shard_map``-wrapped computation, so
        embedding it in a caller's ``jit`` (e.g. the train step)
        inherits the spec's layouts."""
        if self.mesh is None:
            return self._replay(params, x)
        data_dim = self.spec.mesh[0]
        if x.shape[0] % data_dim:
            raise ValueError(
                f"batch {x.shape[0]} does not divide over the data "
                f"axis of {data_dim} (program "
                f"{self.spec.model}/{self.spec.role} mesh "
                f"{self.mesh_str})")
        pspecs = {k: self._param_pspecs.get(k, P()) for k in params}
        fn = _shard_map(self._replay, mesh=self.mesh,
                        in_specs=(pspecs, P("data")),
                        out_specs=P("data"))
        return fn(params, x)

    def _replay(self, params, x):
        """The per-device layer replay (the whole computation when
        unsharded; the shard-local body under ``shard_map`` when not).
        Inside shard_map, ``x`` is the local batch shard and
        ``"cout"``-layers' params are local Cout shards.

        The spec's storage precision is applied here: inputs and
        weights are cast to ``spec.dtype`` at use, the projection
        contracts with an f32 accumulator (``preferred_element_type``,
        matching the conv backends' f32 scratch), and biases stay f32
        into the fused epilogues.  Bit-identical to the historic path
        for f32 specs."""
        spec = self.spec
        sd = self._storage
        sharded = self.mesh is not None
        x = x.astype(sd)
        if spec.role == "generator":
            first = spec.layers[0]
            x = jnp.dot(x, params["proj_w"].astype(sd),
                        preferred_element_type=jnp.float32)
            x = x + params["proj_b"].astype(jnp.float32)
            x = x.reshape((x.shape[0],) + first.in_spatial
                          + (first.cin,))
            x = jax.nn.relu(x).astype(sd)
        batch = x.shape[0]
        for le, policy in zip(spec.layers, self._policies):
            w = params[le.w_param].astype(sd)
            b = params[le.b_param] if le.bias else None
            op = df_tconv if le.kind == "tconv" else df_conv
            # Host-side span: under jit this records *trace* time (how
            # long building this layer's computation took), exactly once
            # per executable — it never enters the jaxpr.
            with _obs.trace("program.layer", layer=le.name, kind=le.kind,
                            backend=le.backend, source=le.source,
                            measured_us=le.measured_us):
                x = op(x, w, le.strides, le.paddings, policy=policy,
                       blocks=le.blocks, bias=b, epilogue=le.epilogue)
                if sharded and le.sharding == "cout":
                    # each device computed cout/model output channels
                    # (epilogue included — bias was sharded alongside);
                    # restore full Cout for the next layer.  No halo:
                    # Cout is a pure output dimension.
                    x = jax.lax.all_gather(x, "model", axis=x.ndim - 1,
                                           tiled=True)
        if spec.role == "discriminator":
            # logits reduce in f32 (a bf16 mean over every pixel would
            # lose the signal) and *stay* f32 — losses are always
            # computed at full precision
            x = x.reshape(batch, -1).mean(axis=-1, dtype=jnp.float32)
        return x

    def apply(self, params, x):
        """The jitted executable: one trace per input shape, then the
        cached computation — serving's hot path.

        The disabled-tracing path is a single boolean check away from
        the raw jitted callable (the microbench gate pins its cost on
        ``program_us`` under 2%); with tracing on, each call gets a
        ``program.apply`` span whose ``traced`` attr flags the calls
        that paid trace+compile time."""
        if not _obs.is_enabled():
            return self._apply(params, x)
        traces_before = self.traces
        with _obs.trace("program.apply", model=self.spec.model,
                        role=self.spec.role, batch=int(x.shape[0]),
                        devices=self.device_count,
                        mesh=self.mesh_str) as sp:
            out = self._apply(params, x)
            sp.set(traced=self.traces > traces_before)
        return out

    # -- passthroughs -------------------------------------------------------
    def describe(self) -> str:
        return self.spec.describe()

    def save(self, path) -> None:
        self.spec.save(path)

    def __repr__(self) -> str:
        quant = ", quant=int8" if self.quantized else ""
        return (f"Program({self.spec.model}/{self.spec.role}, "
                f"{len(self.spec.layers)} layers, "
                f"{self.spec.summary()}, dtype={self.spec.dtype}"
                f"{quant}, traces={self.traces})")


def build_bucket_programs(spec: ProgramSpec, buckets, *,
                          differentiable: bool = False
                          ) -> dict[int, "Program"]:
    """One :class:`Program` per batch-size bucket, all from **one**
    frozen spec.

    The continuous-batching serving engine
    (:class:`repro.serve.gan_engine.GanEngine`) coalesces requests into
    a small set of batch-size buckets.  Resolution (the config → policy
    → plan walk) happened once when ``spec`` was built; this helper
    only fans the frozen records out into one jitted executable per
    bucket, so each bucket traces exactly once — ``programs[b].traces``
    stays at 1 however many requests ride that bucket (pinned by the
    engine tests) and the ``program.retraces`` counter never fires on
    the serving path.

    ``buckets`` is deduplicated and sorted ascending; every bucket must
    be a positive int.
    """
    sizes = sorted({int(b) for b in buckets})
    if not sizes or sizes[0] <= 0:
        raise ValueError(f"buckets must be positive ints, got "
                         f"{tuple(buckets)}")
    return {b: Program(spec, differentiable=differentiable)
            for b in sizes}


def load_or_build(path, cfg, batch: int, role: str = "generator", *,
                  policy: DataflowPolicy | None = None, planner=None,
                  measure: bool = False, dtype: str | None = None,
                  differentiable: bool = True,
                  mesh=_SPEC_UNSET) -> tuple[Program, bool]:
    """Load an exported program file, falling back to fresh resolution.

    Returns ``(program, loaded)``.  ``loaded=False`` means the file was
    missing, corrupt, version-skewed, named unknown backends/stale
    blocks, or froze a different workload than ``cfg`` builds now
    (topology / channel-scale / epilogue / storage-precision drift —
    the requested ``dtype`` defaults to ``cfg.dtype``, so a file at
    the wrong precision degrades too) — in every such case the
    program is rebuilt from ``cfg`` exactly as :meth:`Program.build`
    would, so a bad file degrades the optimization, never the service.

    The mesh is deliberately **not** part of the workload identity: a
    file exported with a mesh loads fine on a config without one (and
    vice versa) — it is the file's frozen sharding decision that wins,
    degrading to single-device if this process lacks the devices.
    ``mesh`` only shapes the *fallback* rebuild."""
    fresh = ProgramSpec.build(cfg, batch, role, policy=policy,
                              planner=planner, measure=False,
                              dtype=dtype, mesh=mesh)
    try:
        spec = ProgramSpec.load(path)
        if spec.geometry_signature() != fresh.geometry_signature():
            raise ValueError("program file froze a different workload "
                             "than this config builds")
    except Exception as e:   # corrupt/stale file → fresh resolution
        log.warning("ignoring program file %s (%s: %s); rebuilding from "
                    "config", path, type(e).__name__, e)
        if measure:   # the fallback still honors the warmup request
            fresh = ProgramSpec.build(cfg, batch, role, policy=policy,
                                      planner=planner, measure=True,
                                      dtype=dtype, mesh=mesh)
        return Program(fresh, differentiable=differentiable), False
    return Program(spec, differentiable=differentiable), True
