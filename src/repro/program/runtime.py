"""The executable form of a :class:`~repro.program.ProgramSpec`.

A :class:`Program` binds a frozen spec to **one** jitted callable:
``program.apply(params, x)`` traces the whole network once (per input
shape/dtype) and replays the compiled executable afterwards — no
per-call config → policy → plan threading anywhere on the hot path.
The per-layer policies are concrete pinned backends (the spec resolved
them ahead of time), so tracing never touches the autotuning planner:
an exported program serves on a planner-less process with zero
measurements.
"""

from __future__ import annotations

import logging

import jax

from repro import obs as _obs
from repro.core.dataflow import DataflowPolicy
from repro.core.dataflow import conv as df_conv
from repro.core.dataflow import tconv as df_tconv
from repro.program.spec import ProgramSpec

__all__ = ["Program", "build_bucket_programs", "load_or_build"]

log = logging.getLogger(__name__)


class Program:
    """One GAN network as an ahead-of-time compiled executable.

    ``forward`` is the traceable (unjitted) computation — use it inside
    a larger ``jit`` (a train step, a loss);  ``apply`` is the jitted
    standalone entry point serving uses.  ``traces`` counts actual
    traces of ``apply`` — the executable-reuse contract is testable:
    repeated same-shape calls keep it at 1.
    """

    def __init__(self, spec: ProgramSpec, *, differentiable: bool = True):
        self.spec = spec
        self.differentiable = bool(differentiable)
        self._policies = tuple(
            DataflowPolicy(backend=le.backend,
                           differentiable=self.differentiable)
            for le in spec.layers)
        self.traces = 0

        def _traced(params, x):
            # Runs once per input shape (trace time, not per call) —
            # cheap enough to always count, visible in ``--stats``.
            self.traces += 1
            _obs.counter("program.traces").inc()
            if self.traces > 1:
                _obs.counter("program.retraces").inc()
            return self.forward(params, x)
        self._apply = jax.jit(_traced)

    @classmethod
    def build(cls, cfg, batch: int, role: str = "generator", *,
              policy: DataflowPolicy | None = None, planner=None,
              measure: bool = False, dtype: str = "float32",
              differentiable: bool = True) -> "Program":
        """:meth:`ProgramSpec.build` + wrap — the one-call form."""
        spec = ProgramSpec.build(cfg, batch, role, policy=policy,
                                 planner=planner, measure=measure,
                                 dtype=dtype)
        return cls(spec, differentiable=differentiable)

    # -- execution ----------------------------------------------------------
    def forward(self, params, x):
        """Replay the frozen layer records (traceable; donate to ``jit``
        via :meth:`apply` or embed in a caller's trace)."""
        spec = self.spec
        if spec.role == "generator":
            first = spec.layers[0]
            x = x @ params["proj_w"] + params["proj_b"]
            x = x.reshape((x.shape[0],) + first.in_spatial
                          + (first.cin,))
            x = jax.nn.relu(x)
        batch = x.shape[0]
        for le, policy in zip(spec.layers, self._policies):
            w = params[le.w_param]
            b = params[le.b_param] if le.bias else None
            op = df_tconv if le.kind == "tconv" else df_conv
            # Host-side span: under jit this records *trace* time (how
            # long building this layer's computation took), exactly once
            # per executable — it never enters the jaxpr.
            with _obs.trace("program.layer", layer=le.name, kind=le.kind,
                            backend=le.backend, source=le.source,
                            measured_us=le.measured_us):
                x = op(x, w, le.strides, le.paddings, policy=policy,
                       blocks=le.blocks, bias=b, epilogue=le.epilogue)
        if spec.role == "discriminator":
            x = x.reshape(batch, -1).mean(axis=-1)
        return x

    def apply(self, params, x):
        """The jitted executable: one trace per input shape, then the
        cached computation — serving's hot path.

        The disabled-tracing path is a single boolean check away from
        the raw jitted callable (the microbench gate pins its cost on
        ``program_us`` under 2%); with tracing on, each call gets a
        ``program.apply`` span whose ``traced`` attr flags the calls
        that paid trace+compile time."""
        if not _obs.is_enabled():
            return self._apply(params, x)
        traces_before = self.traces
        with _obs.trace("program.apply", model=self.spec.model,
                        role=self.spec.role,
                        batch=int(x.shape[0])) as sp:
            out = self._apply(params, x)
            sp.set(traced=self.traces > traces_before)
        return out

    # -- passthroughs -------------------------------------------------------
    def describe(self) -> str:
        return self.spec.describe()

    def save(self, path) -> None:
        self.spec.save(path)

    def __repr__(self) -> str:
        return (f"Program({self.spec.model}/{self.spec.role}, "
                f"{len(self.spec.layers)} layers, "
                f"{self.spec.summary()}, traces={self.traces})")


def build_bucket_programs(spec: ProgramSpec, buckets, *,
                          differentiable: bool = False
                          ) -> dict[int, "Program"]:
    """One :class:`Program` per batch-size bucket, all from **one**
    frozen spec.

    The continuous-batching serving engine
    (:class:`repro.serve.gan_engine.GanEngine`) coalesces requests into
    a small set of batch-size buckets.  Resolution (the config → policy
    → plan walk) happened once when ``spec`` was built; this helper
    only fans the frozen records out into one jitted executable per
    bucket, so each bucket traces exactly once — ``programs[b].traces``
    stays at 1 however many requests ride that bucket (pinned by the
    engine tests) and the ``program.retraces`` counter never fires on
    the serving path.

    ``buckets`` is deduplicated and sorted ascending; every bucket must
    be a positive int.
    """
    sizes = sorted({int(b) for b in buckets})
    if not sizes or sizes[0] <= 0:
        raise ValueError(f"buckets must be positive ints, got "
                         f"{tuple(buckets)}")
    return {b: Program(spec, differentiable=differentiable)
            for b in sizes}


def load_or_build(path, cfg, batch: int, role: str = "generator", *,
                  policy: DataflowPolicy | None = None, planner=None,
                  measure: bool = False, dtype: str = "float32",
                  differentiable: bool = True) -> tuple[Program, bool]:
    """Load an exported program file, falling back to fresh resolution.

    Returns ``(program, loaded)``.  ``loaded=False`` means the file was
    missing, corrupt, version-skewed, named unknown backends/stale
    blocks, or froze a different workload than ``cfg`` builds now
    (topology / channel-scale / epilogue drift) — in every such case the
    program is rebuilt from ``cfg`` exactly as :meth:`Program.build`
    would, so a bad file degrades the optimization, never the service.
    """
    fresh = ProgramSpec.build(cfg, batch, role, policy=policy,
                              planner=planner, measure=False,
                              dtype=dtype)
    try:
        spec = ProgramSpec.load(path)
        if spec.geometry_signature() != fresh.geometry_signature():
            raise ValueError("program file froze a different workload "
                             "than this config builds")
    except Exception as e:   # corrupt/stale file → fresh resolution
        log.warning("ignoring program file %s (%s: %s); rebuilding from "
                    "config", path, type(e).__name__, e)
        if measure:   # the fallback still honors the warmup request
            fresh = ProgramSpec.build(cfg, batch, role, policy=policy,
                                      planner=planner, measure=True,
                                      dtype=dtype)
        return Program(fresh, differentiable=differentiable), False
    return Program(spec, differentiable=differentiable), True
