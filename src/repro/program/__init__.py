"""`repro.program` — ahead-of-time compiled GAN executables.

The public way to run a GAN in this repo, replacing per-call
config → policy → epilogue → plan threading with GANAX-style
ahead-of-time specialization:

* :class:`ProgramSpec` (:mod:`repro.program.spec`) — ``build(cfg,
  batch, role)`` walks the layers **once** and freezes a tuple of
  :class:`LayerExec` records (geometry, fused epilogue, the resolved
  concrete backend + Pallas blocks, provenance).  Specs round-trip
  through JSON: tune on one box, export, serve on another — with zero
  re-measurement.
* :class:`Program` (:mod:`repro.program.runtime`) — wraps a spec into
  one jitted callable ``apply(params, x)`` plus ``describe()``.
* :func:`load_or_build` — the degrading loader: corrupt / stale /
  mismatched program files fall back to fresh resolution.
* :func:`build_bucket_programs` — fan one frozen spec out into one
  executable per batch-size bucket (the continuous-batching serving
  engine's ahead-of-time bucket set).
* ``python -m repro.program <model>`` — build + describe (and
  export/load) programs from the command line.
"""

from repro.program.runtime import (Program, build_bucket_programs,
                                   load_or_build)
from repro.program.spec import (PROGRAM_FORMAT_VERSION, LayerExec,
                                ProgramSpec)

__all__ = ["LayerExec", "Program", "ProgramSpec", "load_or_build",
           "build_bucket_programs", "PROGRAM_FORMAT_VERSION"]
