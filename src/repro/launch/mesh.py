"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set ``XLA_FLAGS`` before any jax initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "POD_STRIDE"]

# device-id stride between pods in the multi-pod mesh (pod axis is
# slowest-varying): used to classify collectives as ICI vs DCN.
POD_STRIDE = 256


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16×16 per pod, ×2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int | None = None):
    """A ``("data", "model")`` mesh over local devices (tests / CPU
    examples / single-host serving).

    Four forms, by which axes are pinned:

    * ``make_local_mesh()`` — factor *all* local devices: ``model`` is
      the largest of 4, 2 that divides the device count, ``data`` the
      quotient.  **Odd device counts (and 1) fall back to
      ``model=1``** — every device goes to the ``data`` axis and
      Cout-model-parallel layers have nothing to shard over.  This
      silent fallback is intentional (a degraded mesh beats a crash on
      a 6-core runner) but means ``model > 1`` must never be *assumed*
      from the no-argument form.
    * ``make_local_mesh(data=N)`` — pure data-parallel convenience:
      exactly ``(N, 1)``, the common GAN serving mesh.
    * ``make_local_mesh(model=M)`` — all devices, ``M``-way model
      axis: ``(n // M, M)``; raises if ``M`` does not divide the
      device count.
    * ``make_local_mesh(data=N, model=M)`` — the exact requested shape
      over the first ``N·M`` devices; raises if that many do not
      exist.  (Explicit ``devices=`` slice: ``jax.make_mesh`` would
      silently take a prefix anyway, this just makes it deliberate and
      checked.)
    """
    n = len(jax.devices())
    if data is None and model is None:
        model = 1
        data = n
        for m in (4, 2):
            if n % m == 0 and n >= m:
                model = m
                data = n // m
                break
    elif model is None:
        model = 1
    elif data is None:
        if n % model:
            raise ValueError(f"model={model} does not divide the "
                             f"{n} local devices")
        data = n // model
    need = data * model
    if need > n:
        raise ValueError(f"mesh ({data}, {model}) needs {need} devices; "
                         f"only {n} available")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:need])
