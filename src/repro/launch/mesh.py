"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set ``XLA_FLAGS`` before any jax initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "POD_STRIDE"]

# device-id stride between pods in the multi-pod mesh (pod axis is
# slowest-varying): used to classify collectives as ICI vs DCN.
POD_STRIDE = 256


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16×16 per pod, ×2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if data is None or model is None:
        model = 1
        data = n
        for m in (4, 2):
            if n % m == 0 and n >= m:
                model = m
                data = n // m
                break
    return jax.make_mesh((data, model), ("data", "model"))
