"""Serving launcher: batched decode with continuous batching.

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-32b \
        --preset tiny --requests 6 --max-new 24
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.launch.train import reduced_config
from repro.models import transformer as tr
from repro.serve.engine import DecodeEngine, EngineConfig, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch, args.preset)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    params = tr.init(cfg, jax.random.PRNGKey(args.seed))
    ecfg = EngineConfig(n_slots=args.slots,
                        max_len=64 + args.max_new,
                        max_new=args.max_new,
                        temperature=args.temperature)
    engine = DecodeEngine(cfg, params, ecfg)

    rng = jax.random.PRNGKey(args.seed + 1)
    reqs = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = 4 + int(jax.random.randint(k, (), 0, 12))
        prompt = list(range(1, plen + 1))
        reqs.append(Request(rid=i, prompt=prompt))

    t0 = time.perf_counter()
    engine.run(reqs, max_steps=args.max_new * args.requests + 64)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.generated) for r in reqs)
    for r in reqs:
        print(f"[serve] req {r.rid}: prompt={len(r.prompt)} "
              f"generated={r.generated[:8]}… ({len(r.generated)} tokens)")
    print(f"[serve] {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, {engine.steps} engine steps)")


if __name__ == "__main__":
    main()
