"""Training launcher.

CPU-sized presets run out of the box; the full assigned configs are the
same code path on a real mesh (see ``launch/dryrun.py`` for the compile
proof).  Examples::

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --preset tiny --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b \
        --preset 100m --steps 300 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM, make_batch_fn
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tr
from repro.sharding import rules as R
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import AdamWConfig
from repro.train.train_state import init_train_state, make_train_step


def reduced_config(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "full":
        return cfg
    if preset == "tiny":
        over = dict(n_layers=2, d_model=128, d_ff=256, vocab=512)
        heads = dict(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
                     head_dim=32)
    elif preset == "100m":
        over = dict(n_layers=12, d_model=768, d_ff=2048, vocab=32000)
        heads = dict(n_heads=12, n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
                     head_dim=64)
    else:
        raise ValueError(preset)
    if cfg.n_heads:
        over.update(heads)
    if cfg.mla:
        over.update(q_lora_rank=over["d_model"] // 2,
                    kv_lora_rank=over["d_model"] // 4,
                    qk_nope_head_dim=32, qk_rope_head_dim=16,
                    v_head_dim=32)
    if cfg.moe:
        over.update(n_experts=8, top_k=min(cfg.top_k, 2),
                    expert_d_ff=over["d_ff"] // 4)
    if cfg.ssm:
        over.update(ssm_state=16, ssm_head_dim=32)
    if cfg.local_window:
        over.update(local_window=128)
    if cfg.global_layers:
        over.update(global_layers=(0, over["n_layers"] - 1))
    if cfg.img_tokens:
        over.update(img_tokens=16, frontend_dim=128)
    if cfg.frontend_dim and not cfg.img_tokens:
        over.update(frontend_dim=128)
    return dataclasses.replace(cfg, **over)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch, args.preset)
    mesh = make_local_mesh()
    print(f"[train] arch={args.arch} preset={args.preset} "
          f"params={tr.count_params(cfg):,} mesh={dict(mesh.shape)}")

    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)
    flags = tr.RunFlags(attn_impl="flash", remat=True, mesh=mesh)
    step_fn = make_train_step(cfg, opt_cfg, flags,
                              grad_accum=args.grad_accum)
    rules = R.Rules()
    with mesh:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        src = SyntheticLM(cfg, args.batch, args.seq, seed=args.seed,
                          microbatches=args.grad_accum)
        batch_fn = make_batch_fn(src)
        loop = TrainLoop(
            LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, log_every=1),
            jit_step, batch_fn, state)
        loop.run()
    print("[train] done")


if __name__ == "__main__":
    main()
