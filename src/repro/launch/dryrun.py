import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every supported (architecture × input shape) cell this lowers and
compiles the step function on the production mesh (single-pod 16×16 and
multi-pod 2×16×16), records ``memory_analysis()`` / ``cost_analysis()`` and
the HLO-parsed roofline terms (FLOPs / HBM bytes / collective bytes with
while-loop trip multipliers), and writes one JSON artifact per cell under
``artifacts/dryrun/``.

The two XLA_FLAGS lines above MUST stay the first statements in this file:
jax locks the device count on first init.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback

from repro.configs.base import SHAPES, cell_supported, get_config, \
    list_configs
from repro.launch.mesh import POD_STRIDE, make_production_mesh
from repro.launch.specs import build_cell
from repro.utils.hlo import analyze_hlo

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def run_cell(arch: str, shape: str, multi_pod: bool,
             save_hlo: bool = False, rules=None, flags=None,
             variant: str = "", kv_dtype: str = "bf16") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    plan = build_cell(arch, shape, mesh, rules=rules, flags=flags,
                      kv_dtype=kv_dtype)
    with mesh:
        lowered = plan.fn.lower(*plan.args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    parsed = analyze_hlo(hlo_text, pod_stride=POD_STRIDE if multi_pod
                         else 1 << 62)

    art = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "meta": plan.meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "xla_cost_analysis": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "hlo_parsed": parsed.to_json(),
        "status": "ok",
    }
    if save_hlo:
        hdir = os.path.join(ARTIFACT_DIR, "hlo")
        os.makedirs(hdir, exist_ok=True)
        with open(os.path.join(
                hdir, f"{arch}_{shape}_{art['mesh']}.txt"), "w") as f:
            f.write(hlo_text)
    return art


def artifact_path(arch: str, shape: str, mesh_name: str,
                  variant: str = "") -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"_{variant}" if variant else ""
    return os.path.join(ARTIFACT_DIR,
                        f"{arch}_{shape}_{mesh_name}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list_configs() if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            ok, why = cell_supported(cfg, SHAPES[shape])
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = artifact_path(arch, shape, mesh_name)
                if not ok:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": mesh_name, "status": "skipped",
                                   "reason": why}, f, indent=1)
                    print(f"[dryrun] SKIP {arch}×{shape}×{mesh_name}: {why}")
                    continue
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] exists {arch}×{shape}×{mesh_name}")
                    continue
                cells.append((arch, shape, mp, path))

    n_fail = 0
    for arch, shape, mp, path in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        tag = f"{arch}×{shape}×{mesh_name}"
        try:
            art = run_cell(arch, shape, mp, save_hlo=args.save_hlo)
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            hp = art["hlo_parsed"]
            print(f"[dryrun] OK   {tag}: compile={art['compile_s']}s "
                  f"flops/dev={hp['flops']:.3e} "
                  f"coll={sum(hp['collective_bytes'].values()):.3e}B "
                  f"temp={art['memory_analysis']['temp_bytes']}")
        except Exception as e:
            n_fail += 1
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": str(e)[:2000]}, f,
                          indent=1)
            print(f"[dryrun] FAIL {tag}: {type(e).__name__}: "
                  f"{str(e)[:300]}")
            traceback.print_exc(limit=3)
    print(f"[dryrun] done: {len(cells) - n_fail}/{len(cells)} compiled")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
