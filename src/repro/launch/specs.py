"""Per-cell (architecture × input shape) dry-run plans.

``build_cell`` assembles everything needed to lower one cell on a mesh:
the jitted step function, ``ShapeDtypeStruct`` stand-ins for every input
(weak-type-correct, shardable, zero allocation) and the in/out shardings.

Shape semantics (per the assignment):
  * ``train_*``   → ``train_step`` (fwd+bwd+AdamW, grad-accum microbatches)
  * ``prefill_*`` → ``prefill_step`` (full-sequence forward + cache build)
  * ``decode_*`` / ``long_*`` → ``serve_step`` (ONE new token against a
    seq_len-deep KV cache)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ArchConfig, ShapeSpec, SHAPES,
                                cell_supported, get_config)
from repro.models import transformer as tr
from repro.models.common import spec_shapes
from repro.sharding import rules as R
from repro.train.optimizer import AdamWConfig
from repro.train.train_state import make_train_step

__all__ = ["CellPlan", "build_cell", "GRAD_ACCUM"]

# Grad-accumulation (microbatch) schedule per arch family for train_4k:
# bigger models → more accumulation so the per-microbatch activation
# footprint fits HBM (memory term, see EXPERIMENTS.md §Dry-run).
GRAD_ACCUM: dict[str, int] = {
    "qwen1.5-32b": 16,
    "internvl2-26b": 16,
    "llama4-scout-17b-a16e": 16,
    "minicpm3-4b": 8,
    "gemma-7b": 8,
    "gemma3-4b": 8,
    "mamba2-2.7b": 4,
    "olmoe-1b-7b": 2,
    "hubert-xlarge": 2,
    "hymba-1.5b": 2,
}


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: str
    fn: Callable                      # jitted (in_shardings applied)
    args: tuple                       # ShapeDtypeStructs
    meta: dict


def _batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh, rules,
                 grad_accum: int):
    """ShapeDtypeStructs + shardings for the input batch."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        mb = gb // grad_accum
        lead = (grad_accum, mb) if grad_accum > 1 else (mb,)
        bdim = 1 if grad_accum > 1 else 0
    else:
        lead = (gb,)
        bdim = 0

    def tok_spec(extra=(), dtype=jnp.int32):
        return jax.ShapeDtypeStruct(lead + (s,) + extra, dtype)

    def shard(ndim):
        return R.batch_sharding(mesh, ndim, rules, batch_dim=bdim,
                                batch_size=lead[bdim])

    if cfg.family == "encoder":
        batch = {
            "features": tok_spec((cfg.frontend_dim,), jnp.float32),
            "labels": tok_spec(),
            "label_mask": tok_spec(dtype=jnp.float32),
        }
    else:
        batch = {"tokens": tok_spec()}
        if cfg.family == "vlm":
            batch["img_embeds"] = jax.ShapeDtypeStruct(
                lead + (cfg.img_tokens, cfg.frontend_dim), jnp.float32)
    shardings = {k: shard(v.ndim) for k, v in batch.items()}
    return batch, shardings


def build_cell(arch: str, shape_name: str, mesh, *,
               rules: R.Rules | None = None,
               flags: tr.RunFlags | None = None,
               donate: bool = True, kv_dtype: str = "bf16") -> CellPlan:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"cell {arch}×{shape_name} unsupported: {why}")
    # training shards params FSDP-style over (data × model); serving keeps
    # bf16 weights replicated across data replicas (no per-step gather).
    rules = rules or R.Rules(allow_uneven=False,
                             fsdp=(shape.kind == "train"))
    long_ctx = shape.name.startswith("long")
    flags = flags or tr.RunFlags(
        attn_impl="flash", remat=True, mesh=mesh,
        seq_shard_decode=long_ctx and cfg.family != "ssm")

    axes = tr.model_axes(cfg)
    shapes = spec_shapes(tr.model_specs(cfg))
    if shape.kind != "train":   # serving weights in bf16
        shapes = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(
                sd.shape, jnp.bfloat16 if sd.dtype == jnp.float32
                else sd.dtype), shapes)
    p_sh = R.param_shardings(mesh, axes, shapes, rules)

    # 6·N per token for training (fwd+bwd), 2·N for forward-only serving
    flops_tok = tr.model_flops_per_token(cfg)
    if shape.kind != "train":
        flops_tok /= 3.0
    meta = {"arch": arch, "shape": shape_name,
            "params": tr.count_params(cfg),
            "model_flops_per_token": flops_tok,
            "mesh": dict(mesh.shape)}

    if shape.kind == "train":
        accum = GRAD_ACCUM.get(arch, 4)
        # the microbatch must still cover the batch mesh axes, or whole
        # pods silently replicate work (caught by the multi-pod roofline:
        # per-device terms failed to halve)
        bs_prod = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                               if a in mesh.shape]))
        accum = max(1, min(accum, shape.global_batch // bs_prod))
        batch, b_sh = _batch_specs(cfg, shape, mesh, rules, accum)
        opt_cfg = AdamWConfig(total_steps=10_000)
        # compute copy: TP-only sharding (FSDP gather hoisted out of the
        # accumulation loop, §Perf HC5); master grads reduce-scattered
        # back to the FSDP layout before AdamW
        nofsdp = dataclasses.replace(rules, fsdp=False)
        c_sh = R.param_shardings(mesh, axes, shapes, nofsdp)
        step_fn = make_train_step(cfg, opt_cfg, flags, grad_accum=accum,
                                  compute_shardings=c_sh,
                                  master_shardings=p_sh)
        o_sh = R.opt_state_shardings(mesh, axes, shapes, rules)
        state_specs = {
            "params": shapes,
            "opt": {"mu": shapes, "nu": shapes,
                    "count": jax.ShapeDtypeStruct((), jnp.int32)},
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        # fp32 moments
        state_specs["opt"]["mu"] = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.float32), shapes)
        state_specs["opt"]["nu"] = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.float32), shapes)
        scalar_sh = NamedSharding(mesh, P())
        state_sh = {
            "params": p_sh,
            "opt": {"mu": o_sh, "nu": o_sh, "count": scalar_sh},
            "step": scalar_sh,
        }
        fn = jax.jit(step_fn, in_shardings=(state_sh, b_sh),
                     donate_argnums=(0,) if donate else ())
        meta["grad_accum"] = accum
        meta["tokens_per_step"] = shape.global_batch * shape.seq_len
        return CellPlan(arch, shape_name, fn, (state_specs, batch), meta)

    if shape.kind == "prefill":
        batch, b_sh = _batch_specs(cfg, shape, mesh, rules, 1)

        def prefill_step(params, batch):
            logits, cache, _ = tr.forward(params, batch, cfg,
                                          mode="prefill", flags=flags,
                                          last_logit_only=True)
            return logits[:, -1], cache

        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        meta["tokens_per_step"] = shape.global_batch * shape.seq_len
        return CellPlan(arch, shape_name, fn, (shapes, batch), meta)

    # decode
    gb, s = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: tr.init_cache(cfg, gb, s, kv_dtype=kv_dtype))
    seq_shard = bool(flags.seq_shard_decode)
    c_sh = R.cache_shardings(mesh, cache_shapes, rules,
                             seq_shard=seq_shard)
    tok = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
    lens = jax.ShapeDtypeStruct((gb,), jnp.int32)
    tok_sh = R.batch_sharding(mesh, 2, rules, batch_size=gb) \
        if not seq_shard else NamedSharding(mesh, P())
    len_sh = R.batch_sharding(mesh, 1, rules, batch_size=gb) \
        if not seq_shard else NamedSharding(mesh, P())

    def serve_step(params, cache, tokens, lengths):
        return tr.decode_step(params, cache, tokens, lengths, cfg, flags)

    fn = jax.jit(serve_step, in_shardings=(p_sh, c_sh, tok_sh, len_sh),
                 donate_argnums=(1,) if donate else ())
    meta["tokens_per_step"] = gb
    meta["cache_len"] = s
    meta["seq_shard"] = seq_shard
    return CellPlan(arch, shape_name, fn, (shapes, cache_shapes, tok, lens),
                    meta)
