"""``python -m repro.obs <file>`` — summarize (and convert) obs traces.

Typical use::

    REPRO_OBS=run.jsonl PYTHONPATH=src python benchmarks/microbench.py
    PYTHONPATH=src python -m repro.obs run.jsonl          # text summary
    PYTHONPATH=src python -m repro.obs run.jsonl \
        --perfetto run.trace.json     # open in https://ui.perfetto.dev

Accepts either on-disk form (JSONL or Chrome/Perfetto trace_event
JSON) — the format is sniffed, so a ``.trace.json`` produced by
``--perfetto`` can itself be summarized.
"""

from __future__ import annotations

import argparse
import sys

from repro.obs.export import (read_records, summarize, write_jsonl,
                              write_trace_events)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize an obs trace (JSONL or trace_event "
                    "JSON) and optionally convert between the two "
                    "formats.")
    ap.add_argument("file", help="trace file (JSONL or trace_event)")
    ap.add_argument("--perfetto", metavar="OUT", default=None,
                    help="also write a Chrome/Perfetto trace_event "
                         "JSON file (open in chrome://tracing or "
                         "ui.perfetto.dev)")
    ap.add_argument("--jsonl", metavar="OUT", default=None,
                    help="also write the records back out as JSONL "
                         "(trace_event → JSONL conversion)")
    ap.add_argument("--top", type=int, default=20,
                    help="rows per summary table (default 20)")
    args = ap.parse_args(argv)

    try:
        records = read_records(args.file)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {args.file}: {e}", file=sys.stderr)
        return 1
    print(summarize(records, top=args.top))
    if args.perfetto:
        write_trace_events(records, args.perfetto)
        print(f"\nwrote {args.perfetto} (open in ui.perfetto.dev)")
    if args.jsonl:
        write_jsonl(records, args.jsonl)
        print(f"wrote {args.jsonl}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
