"""Metrics registry: counters, gauges, and fixed-bucket histograms.

Zero-dependency (stdlib only) and always live: recording a metric is a
lock + integer/float update, cheap enough that instrumented subsystems
(serving counters, resolution provenance, step-time histograms) count
unconditionally — only *span emission* is gated by the tracer's enabled
flag.  That keeps attribute-style APIs (``GanServer.samples_served``)
and CLI stats (``python -m repro.program <m> --stats``) correct whether
or not a trace sink is attached.

Histograms are fixed-bucket: ``observe`` is O(log #buckets) (bisect)
and percentile extraction interpolates linearly inside the bucket that
contains the requested rank, clamped to the observed min/max — the
error is bounded by one bucket width (pinned against a numpy reference
in tests).

The :class:`Registry` keys metrics on ``(name, sorted labels)`` so
multiple instances (two servers, two planners) can share a metric name
without sharing counts.  ``snapshot()`` returns deep-copied plain data
— safe to read mid-step from another thread; ``register_collector``
attaches external stat sources (the dataflow μop cache, the autotuning
planner) that ``collect()`` snapshots on demand, replacing ad-hoc
private poking by observers like the train loop.
"""

from __future__ import annotations

import bisect
import math
import threading
from typing import Callable, Iterable, Mapping, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Registry",
           "DEFAULT_LATENCY_BOUNDS_US", "metric_key"]


def _bounds(lo: float, hi: float, per_decade: int = 9) -> tuple:
    """Log-spaced 1-2-5 style bucket bounds covering [lo, hi]."""
    out, decade = [], lo
    steps = (1.0, 1.5, 2.0, 3.0, 4.0, 5.0, 6.5, 8.0)[:per_decade]
    while decade <= hi:
        out.extend(decade * s for s in steps)
        decade *= 10.0
    return tuple(b for b in out if lo <= b <= hi)


# Default bounds for microsecond latencies: 1us .. 100s, ~8 buckets per
# decade — fine enough that p50/p99 land within a few percent.
DEFAULT_LATENCY_BOUNDS_US = _bounds(1.0, 1e8)


def metric_key(name: str, labels: Mapping[str, object]
               ) -> tuple[str, tuple]:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


class Counter:
    """Monotonic integer counter."""

    __slots__ = ("name", "labels", "_value", "_lock")
    kind = "counter"

    def __init__(self, name: str, labels: Mapping | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def to_json(self) -> dict:
        return {"value": self._value}


class Gauge:
    """Last-value gauge."""

    __slots__ = ("name", "labels", "_value", "_lock")
    kind = "gauge"

    def __init__(self, name: str, labels: Mapping | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def to_json(self) -> dict:
        return {"value": self._value}


class Histogram:
    """Fixed-bucket histogram with percentile extraction.

    ``bounds`` are the upper edges of the finite buckets (ascending);
    values above the last bound land in an overflow bucket whose upper
    edge is the observed max.  ``percentile(p)`` uses numpy's "linear"
    rank convention (rank = p/100 · (n-1)) and interpolates inside the
    containing bucket, so the error is at most that bucket's width.
    """

    __slots__ = ("name", "labels", "bounds", "_counts", "_count",
                 "_sum", "_min", "_max", "_lock")
    kind = "histogram"

    def __init__(self, name: str, labels: Mapping | None = None,
                 bounds: Sequence[float] | None = None):
        self.name = name
        self.labels = dict(labels or {})
        bounds = tuple(float(b) for b in
                       (bounds if bounds is not None
                        else DEFAULT_LATENCY_BOUNDS_US))
        if list(bounds) != sorted(bounds) or len(set(bounds)) != \
                len(bounds):
            raise ValueError(f"histogram bounds must be strictly "
                             f"ascending, got {bounds}")
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)   # +1 = overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else math.nan

    def percentile(self, p: float) -> float:
        """Approximate p-th percentile (numpy 'linear' rank), bounded
        by one bucket width."""
        if not 0 <= p <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        with self._lock:
            counts = list(self._counts)
            count, vmin, vmax = self._count, self._min, self._max
        if not count:
            return math.nan
        rank = (p / 100.0) * (count - 1)
        cum = 0
        for i, c in enumerate(counts):
            if not c:
                continue
            if rank < cum + c:
                lo = vmin if i == 0 else self.bounds[i - 1]
                hi = vmax if i == len(self.bounds) else self.bounds[i]
                frac = (rank - cum + 0.5) / c   # mid-rank within bucket
                v = lo + frac * (hi - lo)
                return min(max(v, vmin), vmax)
            cum += c
        return vmax

    def percentiles(self) -> dict[str, float]:
        return {"p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}

    def to_json(self) -> dict:
        with self._lock:
            d = {"count": self._count, "sum": self._sum,
                 "min": self._min if self._count else None,
                 "max": self._max if self._count else None,
                 "bounds": list(self.bounds),
                 "counts": list(self._counts)}
        if self._count:
            d.update({k: v for k, v in self.percentiles().items()})
        return d


class Registry:
    """Get-or-create store of metrics keyed on (name, labels), plus
    collector hooks for external stat sources."""

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._collectors: dict[str, Callable[[], Mapping | None]] = {}
        self._lock = threading.Lock()

    # -- get-or-create ------------------------------------------------------
    def _get(self, cls, name: str, labels: Mapping, **kw):
        key = metric_key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, labels, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r}{dict(labels)} already "
                                f"registered as {type(m).__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: Sequence[float] | None = None,
                  **labels) -> Histogram:
        h = self._get(Histogram, name, labels, bounds=bounds)
        if bounds is not None and tuple(float(b) for b in bounds) != \
                h.bounds:
            raise ValueError(f"histogram {name!r} already registered "
                             f"with different bounds")
        return h

    def metrics(self) -> Iterable:
        with self._lock:
            return list(self._metrics.values())

    # -- snapshots ----------------------------------------------------------
    def snapshot(self) -> dict[str, dict]:
        """Deep-copied plain-data view: ``{"counters": {label-qualified
        name: value}, "gauges": {...}, "histograms": {...}}`` — safe to
        hold across steps (copies, never aliases live state)."""
        out: dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for m in self.metrics():
            label = ",".join(f"{k}={v}"
                             for k, v in sorted(m.labels.items()))
            qual = f"{m.name}{{{label}}}" if label else m.name
            if m.kind == "counter":
                out["counters"][qual] = m.value
            elif m.kind == "gauge":
                out["gauges"][qual] = m.value
            else:
                out["histograms"][qual] = m.to_json()
        return out

    # -- collectors ---------------------------------------------------------
    def register_collector(self, name: str,
                           fn: Callable[[], Mapping | None]) -> None:
        """Attach an external stats source (e.g. an LRU cache's info or
        a planner's counters).  ``fn`` returns a mapping or None
        (source not alive); ``collect`` copies whatever it returns."""
        with self._lock:
            self._collectors[name] = fn

    def collect(self) -> dict[str, dict]:
        """``{collector name: copied stats dict}`` for every collector
        whose source is alive right now.  Every returned dict is a fresh
        copy — mid-step readers get a consistent snapshot, never an
        alias of live mutable state."""
        with self._lock:
            collectors = dict(self._collectors)
        out = {}
        for name, fn in collectors.items():
            stats = fn()
            if stats is not None:
                out[name] = dict(stats)
        return out

    def reset(self) -> None:
        """Drop every metric (collectors survive) — test isolation."""
        with self._lock:
            self._metrics.clear()
