"""``repro.obs`` — unified tracing + metrics for the GANAX stack.

GANAX's claim is about *where the cycles go*; this package is how the
reproduction answers that per layer, per request, and per run instead
of only through end-of-run ``BENCH_*.json`` aggregates.  Two halves:

* **Span tracer** (:mod:`repro.obs.tracer`) — ``obs.trace(name,
  **attrs)`` context manager/decorator with thread-local span stacks
  and monotonic-clock timing.  **Off by default** and near-free when
  disabled; spans are host-side only (no JAX primitives), so enabling
  tracing never changes a jaxpr, and a span inside a jitted function
  records trace time exactly once — never per compiled execution.
* **Metrics registry** (:mod:`repro.obs.metrics`) — counters, gauges,
  fixed-bucket histograms (p50/p90/p99), keyed on (name, labels).
  Metrics are always live (cheap lock + add), replacing the scattered
  ad-hoc counters that used to live on ``GanServer``, the planner, and
  the μop cache; ``register_collector``/:func:`collect` snapshot
  external stat sources (copies, never aliases).

Enabling::

    REPRO_OBS=1             # in-memory sink (programmatic inspection)
    REPRO_OBS=run.jsonl     # live JSONL trace file
    obs.enable(sink=...)    # explicit: None=memory, path=JSONL, object

Reading a trace::

    python -m repro.obs run.jsonl                  # text summary
    python -m repro.obs run.jsonl --perfetto out.trace.json
    # open out.trace.json in https://ui.perfetto.dev

``obs.profile(outdir)`` additionally captures the device-side JAX
profiler trace (``jax.profiler.start_trace``/``stop_trace``) with
``obs.annotate(name)`` regions.

Instrumented subsystems and their metric names are tabulated in the
README's "Observability" section.
"""

from __future__ import annotations

import os

from repro.obs.export import (from_trace_events, read_records,
                              summarize, to_trace_events, write_jsonl,
                              write_trace_events)
from repro.obs.jaxbridge import annotate, profile
from repro.obs.metrics import (DEFAULT_LATENCY_BOUNDS_US, Counter,
                               Gauge, Histogram, Registry)
from repro.obs.tracer import (JsonlSink, MemorySink, Span, disable,
                              emit_span, enable, event, flush_metrics,
                              get_sink, is_enabled, now_us, registry,
                              trace)

__all__ = [
    "trace", "event", "enable", "disable", "is_enabled", "get_sink",
    "flush_metrics", "Span", "MemorySink", "JsonlSink",
    "now_us", "emit_span",
    "counter", "gauge", "histogram", "snapshot", "collect",
    "register_collector", "registry", "Registry", "Counter", "Gauge",
    "Histogram", "DEFAULT_LATENCY_BOUNDS_US",
    "to_trace_events", "from_trace_events", "read_records",
    "write_jsonl", "write_trace_events", "summarize",
    "profile", "annotate",
]


# -- module-level conveniences over the process-wide registry ---------------

def counter(name: str, **labels) -> Counter:
    return registry.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return registry.gauge(name, **labels)


def histogram(name: str, bounds=None, **labels) -> Histogram:
    return registry.histogram(name, bounds=bounds, **labels)


def snapshot() -> dict:
    """Deep-copied plain-data view of every metric."""
    return registry.snapshot()


def collect() -> dict:
    """Copied stats from every registered external collector (μop
    cache, autotuning planner, ...)."""
    return registry.collect()


def register_collector(name, fn) -> None:
    registry.register_collector(name, fn)


# -- environment opt-in -----------------------------------------------------
# REPRO_OBS=1/true/yes/on → enabled with an in-memory sink;
# any other non-empty, non-zero value → live JSONL file at that path.
_env = os.environ.get("REPRO_OBS", "").strip()
if _env and _env.lower() not in ("0", "false", "no", "off"):
    enable(None if _env.lower() in ("1", "true", "yes", "on") else _env)
del _env
