"""Trace export: JSONL ↔ Chrome/Perfetto ``trace_event`` conversion and
text summaries.

Two on-disk forms, one in-memory record schema (see
:mod:`repro.obs.tracer`):

* **JSONL** — one record per line, append-only (what the
  :class:`~repro.obs.tracer.JsonlSink` writes live).
* **trace_event JSON** — ``{"traceEvents": [...]}``, the format
  ``chrome://tracing`` and https://ui.perfetto.dev open directly.
  Spans become complete (``"ph": "X"``) events, instant events
  ``"ph": "i"``, counters/gauges ``"ph": "C"``; histograms ride as
  instant events carrying their full bucket state in ``args``.  The
  ``cat`` field tags the record type so :func:`from_trace_events` can
  reconstruct the original records — the JSONL → trace_event → JSONL
  round trip is lossless for spans/events and pinned by tests.

:func:`read_records` sniffs the format, so ``python -m repro.obs``
summarizes either file kind.
"""

from __future__ import annotations

import json
import os
from collections import defaultdict

__all__ = ["to_trace_events", "from_trace_events", "read_records",
           "write_jsonl", "write_trace_events", "summarize"]


def to_trace_events(records) -> dict:
    """Convert tracer records to a Chrome ``trace_event`` document."""
    events = []
    pid = os.getpid()
    for r in records:
        t = r.get("type")
        if t == "header":
            pid = r.get("pid", pid)
            events.append({"name": "obs_header", "ph": "i", "ts": 0,
                           "pid": pid, "tid": 0, "s": "g",
                           "cat": "obs.header",
                           "args": {k: v for k, v in r.items()
                                    if k != "type"}})
        elif t == "span":
            events.append({"name": r["name"], "ph": "X", "cat": "obs.span",
                           "ts": r["ts_us"], "dur": r["dur_us"],
                           "pid": pid, "tid": r.get("tid", 0),
                           "args": dict(r.get("attrs", {}),
                                        depth=r.get("depth", 0))})
        elif t == "event":
            events.append({"name": r["name"], "ph": "i", "cat": "obs.event",
                           "ts": r["ts_us"], "pid": pid,
                           "tid": r.get("tid", 0), "s": "t",
                           "args": dict(r.get("attrs", {}))})
        elif t == "metric":
            kind = r.get("kind", "counter")
            if kind in ("counter", "gauge"):
                events.append({"name": r["name"], "ph": "C",
                               "cat": f"obs.metric.{kind}",
                               "ts": r.get("ts_us", 0), "pid": pid,
                               "tid": 0,
                               "args": {"value": r.get("value", 0),
                                        "labels": r.get("labels", {})}})
            else:   # histogram: full state in args
                events.append({"name": r["name"], "ph": "i",
                               "cat": "obs.metric.histogram",
                               "ts": r.get("ts_us", 0), "pid": pid,
                               "tid": 0, "s": "g",
                               "args": {k: v for k, v in r.items()
                                        if k not in ("type", "kind",
                                                     "name")}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def from_trace_events(doc: dict) -> list[dict]:
    """Reconstruct tracer records from a ``trace_event`` document
    (inverse of :func:`to_trace_events` for obs-produced files)."""
    records = []
    for e in doc.get("traceEvents", []):
        cat = e.get("cat", "")
        if cat == "obs.header":
            records.append({"type": "header", **e.get("args", {})})
        elif cat == "obs.span" or (not cat and e.get("ph") == "X"):
            args = dict(e.get("args", {}))
            depth = args.pop("depth", 0)
            records.append({"type": "span", "name": e["name"],
                            "ts_us": e["ts"], "dur_us": e.get("dur", 0),
                            "tid": e.get("tid", 0), "depth": depth,
                            "attrs": args})
        elif cat == "obs.event" or (not cat and e.get("ph") == "i"):
            records.append({"type": "event", "name": e["name"],
                            "ts_us": e["ts"], "tid": e.get("tid", 0),
                            "attrs": dict(e.get("args", {}))})
        elif cat.startswith("obs.metric."):
            kind = cat.rsplit(".", 1)[-1]
            args = dict(e.get("args", {}))
            if kind in ("counter", "gauge"):
                records.append({"type": "metric", "kind": kind,
                                "name": e["name"],
                                "labels": args.get("labels", {}),
                                "value": args.get("value", 0)})
            else:
                records.append({"type": "metric", "kind": "histogram",
                                "name": e["name"], **args})
    return records


def read_records(path) -> list[dict]:
    """Load tracer records from a JSONL or trace_event file (format
    sniffed from the first non-space byte: ``{`` = one JSON document =
    trace_event)."""
    with open(path) as f:
        text = f.read()
    stripped = text.lstrip()
    if not stripped:
        return []
    if stripped.startswith("{") and "\n{" not in stripped.rstrip():
        try:
            doc = json.loads(stripped)
        except json.JSONDecodeError:
            doc = None      # fall through to JSONL parsing
        if isinstance(doc, dict) and "traceEvents" in doc:
            return from_trace_events(doc)
        if isinstance(doc, dict):
            return [doc]    # a one-line JSONL stream
    records = []
    for line in text.splitlines():
        line = line.strip()
        if line:
            records.append(json.loads(line))
    return records


def write_jsonl(records, path) -> None:
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r, default=str) + "\n")


def write_trace_events(records, path) -> None:
    with open(path, "w") as f:
        json.dump(to_trace_events(records), f, indent=1, default=str)
        f.write("\n")


def summarize(records, top: int = 20) -> str:
    """Human-readable summary: per-span-name aggregate table, event
    counts, and the metric values/percentiles in the stream."""
    spans = [r for r in records if r.get("type") == "span"]
    events = [r for r in records if r.get("type") == "event"]
    metrics = [r for r in records if r.get("type") == "metric"]
    lines = [f"{len(spans)} spans, {len(events)} events, "
             f"{len(metrics)} metrics"]

    agg = defaultdict(lambda: [0, 0.0, 0.0])    # count, total, max
    for s in spans:
        a = agg[s["name"]]
        a[0] += 1
        a[1] += s.get("dur_us", 0.0)
        a[2] = max(a[2], s.get("dur_us", 0.0))
    if agg:
        lines += ["", f"{'span':32s} {'count':>7s} {'total_ms':>10s} "
                      f"{'mean_us':>10s} {'max_us':>10s}"]
        ranked = sorted(agg.items(), key=lambda kv: -kv[1][1])[:top]
        for name, (n, tot, mx) in ranked:
            lines.append(f"{name:32s} {n:7d} {tot / 1e3:10.2f} "
                         f"{tot / n:10.1f} {mx:10.1f}")

    ev = defaultdict(int)
    for e in events:
        ev[e["name"]] += 1
    if ev:
        lines += ["", "events:"]
        for name, n in sorted(ev.items(), key=lambda kv: -kv[1])[:top]:
            lines.append(f"  {name:30s} {n}")

    if metrics:
        lines += ["", "metrics:"]
        for m in metrics:
            labels = m.get("labels") or {}
            lab = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            qual = f"{m['name']}{{{lab}}}" if lab else m["name"]
            if m.get("kind") == "histogram":
                if m.get("count"):
                    lines.append(
                        f"  {qual:40s} count={m['count']} "
                        f"p50={m.get('p50', float('nan')):.1f} "
                        f"p90={m.get('p90', float('nan')):.1f} "
                        f"p99={m.get('p99', float('nan')):.1f}")
                else:
                    lines.append(f"  {qual:40s} count=0")
            else:
                lines.append(f"  {qual:40s} {m.get('value', 0)}")
    return "\n".join(lines)
