"""Span tracer: thread-local span stacks, monotonic timing, sinks.

The tracer is **off by default** and near-free when disabled:
``is_enabled()`` is one module-global read, and every instrumentation
site in the repo checks it (or calls :func:`trace`, whose ``__enter__``
is a single flag check) before formatting any attribute.  No JAX
primitive is ever emitted — spans are host-side only, so the jaxpr of
an instrumented computation is identical with tracing on, off, or
absent (pinned in tests), and a span opened while a function is being
``jit``-traced measures *trace time* exactly once; it can never fire
inside the compiled computation.

Enable with :func:`enable` (``sink=None`` → in-memory,
``sink="path.jsonl"`` → JSONL file, or any object with
``write_record``/``flush``), or via the environment:
``REPRO_OBS=1`` enables with an in-memory sink, any other non-empty
value is treated as a JSONL output path (handled in
``repro.obs.__init__``).  On process exit (or :func:`disable(flush=
True)`) the metrics registry is flushed into the sink as ``metric``
records, so a trace file carries both the spans and the
counters/histograms that accumulated alongside them.

Record schema (plain dicts, one JSON object per JSONL line):

* span   — ``{"type": "span", "name", "ts_us", "dur_us", "tid",
  "depth", "attrs"}``
* event  — ``{"type": "event", "name", "ts_us", "tid", "attrs"}``
  (instant, zero duration)
* metric — ``{"type": "metric", "kind", "name", "labels", ...values}``

``ts_us`` is microseconds on the process-wide monotonic clock, origin
at module import (``epoch_wall_s`` in the stream header maps it to
wall time).
"""

from __future__ import annotations

import atexit
import functools
import json
import os
import threading
import time
from typing import Callable

from repro.obs.metrics import Registry

__all__ = ["trace", "event", "enable", "disable", "is_enabled",
           "get_sink", "MemorySink", "JsonlSink", "Span", "registry",
           "flush_metrics", "now_us", "emit_span"]

_EPOCH_NS = time.perf_counter_ns()
_EPOCH_WALL_S = time.time()

registry = Registry()

_enabled = False
_sink = None
_state = threading.local()          # per-thread span stack
_lock = threading.Lock()


def _now_us() -> float:
    return (time.perf_counter_ns() - _EPOCH_NS) / 1e3


def is_enabled() -> bool:
    """The module-level enabled flag — check this before formatting
    span attributes on a hot path."""
    return _enabled


def _stack() -> list:
    s = getattr(_state, "stack", None)
    if s is None:
        s = _state.stack = []
    return s


# ---------------------------------------------------------------------------
# Sinks.
# ---------------------------------------------------------------------------

class MemorySink:
    """Record-list sink (tests, programmatic inspection)."""

    def __init__(self):
        self.records: list[dict] = []
        self._lock = threading.Lock()

    def write_record(self, record: dict) -> None:
        with self._lock:
            self.records.append(record)

    def flush(self) -> None:
        pass

    def spans(self, name: str | None = None) -> list[dict]:
        with self._lock:
            return [r for r in self.records if r["type"] == "span"
                    and (name is None or r["name"] == name)]

    def events(self, name: str | None = None) -> list[dict]:
        with self._lock:
            return [r for r in self.records if r["type"] == "event"
                    and (name is None or r["name"] == name)]

    def __len__(self) -> int:
        return len(self.records)


class JsonlSink:
    """One JSON object per line, appended as spans close.  The first
    line is a stream header carrying the monotonic→wall mapping."""

    def __init__(self, path):
        self.path = os.fspath(path)
        self._lock = threading.Lock()
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "w")
        self.write_record({"type": "header", "pid": os.getpid(),
                           "epoch_wall_s": _EPOCH_WALL_S})

    def write_record(self, record: dict) -> None:
        line = json.dumps(record, default=str)
        with self._lock:
            self._f.write(line + "\n")

    def flush(self) -> None:
        with self._lock:
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()


def _emit(record: dict) -> None:
    sink = _sink
    if sink is not None:
        sink.write_record(record)


# ---------------------------------------------------------------------------
# Spans.
# ---------------------------------------------------------------------------

class Span:
    """One ``with obs.trace(...)`` region — usable as a context manager
    or a decorator.  When tracing is disabled at ``__enter__`` time the
    span is inert: no clock read, no stack push, no sink write."""

    __slots__ = ("name", "attrs", "_t0_us", "_depth", "_active")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self._active = False

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (no-op when inert)."""
        if self._active:
            self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        if not _enabled:
            return self
        self._active = True
        stack = _stack()
        self._depth = len(stack)
        stack.append(self)
        self._t0_us = _now_us()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._active:
            return
        t1 = _now_us()
        self._active = False
        stack = _stack()
        # tolerate exits out of order (generator-based callers): pop
        # through to this span
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        if _enabled:
            _emit({"type": "span", "name": self.name,
                   "ts_us": self._t0_us, "dur_us": t1 - self._t0_us,
                   "tid": threading.get_ident(), "depth": self._depth,
                   "attrs": self.attrs})

    def __call__(self, fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _enabled:
                return fn(*args, **kwargs)
            with Span(self.name, dict(self.attrs)):
                return fn(*args, **kwargs)
        return wrapper


def trace(name: str, **attrs) -> Span:
    """Open a span: ``with obs.trace("serve.generate", n=n): ...`` or
    ``@obs.trace("tune.measure")``.  Near-free when disabled — prefer
    guarding attribute *formatting* (f-strings, ``describe()`` calls)
    behind :func:`is_enabled` at hot call sites."""
    return Span(name, attrs)


def now_us() -> float:
    """Microseconds on the tracer's process-wide monotonic clock — the
    timebase of every span/event ``ts_us``.  Use with :func:`emit_span`
    to stamp region boundaries that close on a different thread."""
    return _now_us()


def emit_span(name: str, start_us: float, end_us: float | None = None,
              **attrs) -> None:
    """Emit an already-completed span record directly.

    The context-manager form (:func:`trace`) keeps a *thread-local*
    span stack, so it cannot express a region whose start and end
    happen on different threads — e.g. a serving request's
    submit→response lifetime, opened on a producer thread and closed by
    the scheduler.  ``emit_span`` takes explicit boundaries instead
    (``start_us`` from :func:`now_us`; ``end_us`` defaults to now) and
    writes the span at depth 0 on the emitting thread.  No-op when
    disabled."""
    if not _enabled:
        return
    if end_us is None:
        end_us = _now_us()
    _emit({"type": "span", "name": name, "ts_us": float(start_us),
           "dur_us": float(end_us) - float(start_us),
           "tid": threading.get_ident(), "depth": 0, "attrs": attrs})


def event(name: str, **attrs) -> None:
    """Emit an instant (zero-duration) record — checkpoint saved,
    straggler detected, candidate measured.  No-op when disabled."""
    if not _enabled:
        return
    _emit({"type": "event", "name": name, "ts_us": _now_us(),
           "tid": threading.get_ident(), "attrs": attrs})


def current_depth() -> int:
    """Depth of the calling thread's open-span stack (testing aid)."""
    return len(_stack())


# ---------------------------------------------------------------------------
# Enable / disable.
# ---------------------------------------------------------------------------

def enable(sink=None):
    """Turn tracing on.  ``sink``: None → fresh :class:`MemorySink`, a
    str/PathLike → :class:`JsonlSink` at that path, else any object
    with ``write_record(dict)`` / ``flush()``.  Returns the sink."""
    global _enabled, _sink
    with _lock:
        if sink is None:
            sink = MemorySink()
        elif isinstance(sink, (str, os.PathLike)):
            sink = JsonlSink(sink)
        _sink = sink
        _enabled = True
    return sink


def disable(flush: bool = False):
    """Turn tracing off.  ``flush=True`` writes the metrics registry
    into the sink first (the end-of-run dump); the default leaves the
    sink untouched so a disabled process provably writes nothing."""
    global _enabled, _sink
    with _lock:
        sink, _enabled = _sink, False
        if flush and sink is not None:
            _flush_metrics_into(sink)
            sink.flush()
        _sink = None
    return sink


def get_sink():
    return _sink


def _flush_metrics_into(sink) -> None:
    for m in registry.metrics():
        sink.write_record({"type": "metric", "kind": m.kind,
                           "name": m.name, "labels": m.labels,
                           **m.to_json()})


def flush_metrics() -> None:
    """Write the current metrics registry into the active sink as
    ``metric`` records (no-op when disabled)."""
    if _enabled and _sink is not None:
        _flush_metrics_into(_sink)
        _sink.flush()


def _atexit_flush() -> None:
    if _enabled and _sink is not None:
        flush_metrics()
        close = getattr(_sink, "close", None)
        if close is not None:
            close()


atexit.register(_atexit_flush)
