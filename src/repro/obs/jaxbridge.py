"""Opt-in bridge from the obs tracer to the JAX/XLA profiler.

``obs.profile(outdir)`` wraps ``jax.profiler.start_trace`` /
``stop_trace`` around a code region (the resulting TensorBoard/Perfetto
dump shows the *device*-side timeline the host-side obs spans can't
see), and emits a matching ``obs.profile`` span so the two traces can
be aligned.  ``obs.annotate(name)`` returns a
``jax.profiler.TraceAnnotation`` naming a region inside the XLA trace.

Both degrade to host-side-only behavior when the profiler is
unavailable (no jax, or a backend without profiling support): the obs
span still records, the device trace is skipped with a warning attr —
observability must never take the workload down.
"""

from __future__ import annotations

import contextlib

from repro.obs import tracer as _tracer

__all__ = ["profile", "annotate"]


@contextlib.contextmanager
def profile(outdir):
    """Context manager: capture a JAX profiler trace of the region into
    ``outdir`` (viewable in TensorBoard / Perfetto), plus an
    ``obs.profile`` span on the obs timeline."""
    started = False
    err = None
    try:
        import jax
        jax.profiler.start_trace(str(outdir))
        started = True
    except Exception as e:    # no jax / unsupported backend
        err = f"{type(e).__name__}: {e}"
    span = _tracer.trace("obs.profile", outdir=str(outdir),
                         device_trace=started)
    if err is not None:
        span.attrs["error"] = err
    with span:
        try:
            yield
        finally:
            if started:
                import jax
                jax.profiler.stop_trace()


def annotate(name: str):
    """A named region on the device-side profiler timeline
    (``jax.profiler.TraceAnnotation``); a no-op context manager when
    the profiler is unavailable."""
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()
