"""Attention: GQA/MQA/MHA, MLA (MiniCPM3), sliding-window, flash, decode.

Implementations:

* ``flash_attention`` — blocked online-softmax over KV blocks via
  ``lax.scan``: O(S·bk) live memory instead of O(S²); the default for
  training and prefill.
* ``swa_attention`` — *exact* sliding-window attention via the block-local
  trick (each query block attends to itself + the previous block; exact for
  window ≤ block).  FLOPs scale as O(S·2w), not O(S²) — this is what makes
  gemma3/hymba sub-quadratic.
* ``decode_attention`` — single-token attention over a full cache with a
  length mask (S_q = 1, memory-trivial).
* ``flash_decode`` — shard_map'd decode attention over a KV cache whose
  *sequence* dimension is sharded over the ``data`` mesh axis (used for
  long_500k, where batch=1 would otherwise idle the data axis): local
  partial (max, num, den) + psum combine.
* ``mla_*`` — multi-head latent attention: low-rank Q/KV compression with
  decoupled RoPE; the decode path attends in latent space (absorbed
  projections) so the cache is (kv_lora + rope_dim) per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockDesc
from repro.models.common import PSpec, apply_rope, rms_norm, rope_angles

__all__ = ["attention_specs", "attention_apply", "mla_specs", "mla_apply",
           "flash_attention", "swa_attention", "decode_attention",
           "flash_decode"]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core attention math.
# ---------------------------------------------------------------------------

def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def constrain_bthd(x, mesh, batch_axes=("pod", "data"),
                   uneven_heads: bool = False):
    """Pin a (B, T, H, hd) activation's sharding: batch over the data axes,
    heads over `model`.

    Without this, GSPMD can resolve q-vs-cache sharding mismatches by
    ALL-GATHERING the KV cache (observed: 346 GB of gathers per step on
    qwen decode_32k) or by REPLICATING attention across the model axis
    (observed: 3.4× FLOP inflation on qwen train_4k).

    ``uneven_heads=True`` shards the head dim even when it doesn't divide
    the axis — GSPMD pads (idle lanes on the tail shards, e.g. gemma3's
    8 q-heads over 16 shards run at 50% attention occupancy), which is far
    cheaper than replication and legal for intermediates (unlike jit
    inputs, which must divide evenly — so decode CACHES use the even
    head_dim sharding instead).
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    m = mesh.shape.get("model", 1)
    b_ax = tuple(a for a in batch_axes if a in mesh.shape)
    while b_ax and x.shape[0] % int(
            __import__("numpy").prod([mesh.shape[a] for a in b_ax])) != 0:
        b_ax = b_ax[1:]
    if uneven_heads:
        h_ax, hd_ax = "model", None
    else:
        h_ax = "model" if x.shape[2] % m == 0 else None
        hd_ax = "model" if (h_ax is None and x.shape[3] % m == 0) else None
    spec = P(b_ax if len(b_ax) > 1 else (b_ax[0] if b_ax else None),
             None, h_ax, hd_ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def naive_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                    softcap=0.0):
    """Reference full-matrix attention.  q (B,S,Hq,hd), k/v (B,T,Hk,hd)."""
    b, s, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    hv = v.shape[-1]
    rep = hq // hk
    qg = q.reshape(b, s, hk, rep, hd)
    scores = jnp.einsum("bsgrh,btgh->bgrst", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= hd ** -0.5
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = _mask(q_pos, k_pos, causal=causal, window=window)  # (B,S,T)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs, v)
    return out.reshape(b, s, hq, hv)


def _mask(q_pos, k_pos, *, causal, window):
    """(B,S,T) validity mask from absolute positions.

    q_pos: (B,S) int32; k_pos: (B,T) or (T,).
    """
    if k_pos.ndim == 1:
        k_pos = k_pos[None]
    m = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    d = q_pos[:, :, None] - k_pos[:, None, :]
    if causal:
        m &= d >= 0
    if window > 0:
        m &= d < window
    m &= k_pos[:, None, :] >= 0  # negative k_pos marks padding
    return m


def flash_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                    softcap=0.0, block_k=1024):
    """Blocked online-softmax attention (scan over KV blocks)."""
    b, s, hq, hd = q.shape
    t, hk = k.shape[1], k.shape[2]
    hv = v.shape[-1]
    rep = hq // hk
    if t <= block_k:
        return naive_attention(q, k, v, q_pos, k_pos, causal=causal,
                               window=window, softcap=softcap)
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (b, t))
    nb = -(-t // block_k)
    pad = nb * block_k - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    kb = k.reshape(b, nb, block_k, hk, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block_k, hk, hv).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(b, nb, block_k).transpose(1, 0, 2)
    qg = q.reshape(b, s, hk, rep, hd)
    scale = hd ** -0.5

    m0 = jnp.full((b, hk, rep, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, rep, s), jnp.float32)
    a0 = jnp.zeros((b, hk, rep, s, hv), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kk, vv, pp = blk
        sc = jnp.einsum("bsgrh,btgh->bgrst", qg, kk,
                        preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            sc = softcap * jnp.tanh(sc / softcap)
        msk = _mask(q_pos, pp, causal=causal, window=window)
        sc = jnp.where(msk[:, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bgrst,btgh->bgrsh", p.astype(vv.dtype), vv,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, hv).astype(q.dtype)


def chunked_q_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                        softcap=0.0, block_q=1024):
    """Full-row attention computed one q-block at a time (scan).

    Peak live score memory drops from O(S·block_k)·n_live_blocks to
    O(block_q·T) per layer — the CPU-verifiable mitigation for prefill
    temp blow-ups (the Pallas flash kernel is the full fix on TPU; see
    kernels/flash_attention.py and EXPERIMENTS.md §Perf HC4)."""
    b, s, hq, hd = q.shape
    if s <= block_q:
        return naive_attention(q, k, v, q_pos, k_pos, causal=causal,
                               window=window, softcap=softcap)
    nb = -(-s // block_q)
    pad = nb * block_q - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=0)
    qb = q.reshape(b, nb, block_q, hq, hd).swapaxes(0, 1)
    pb = q_pos.reshape(b, nb, block_q).swapaxes(0, 1)

    def body(_, blk):
        qi, pi = blk
        o = naive_attention(qi, k, v, pi, k_pos, causal=causal,
                            window=window, softcap=softcap)
        return (), o

    _, ob = lax.scan(body, (), (qb, pb))
    out = ob.swapaxes(0, 1).reshape(b, nb * block_q, hq, -1)
    return out[:, :s]


def swa_attention(q, k, v, q_pos, k_pos, *, window, softcap=0.0):
    """Exact causal sliding-window attention, block-local formulation.

    FLOPs O(S·2w).  Requires identical q/k lengths (train & prefill).
    """
    b, s, hq, hd = q.shape
    hk = k.shape[2]
    rep = hq // hk
    w = window
    if s <= 2 * w:  # not worth blocking
        return naive_attention(q, k, v, q_pos, k_pos, causal=True,
                               window=window, softcap=softcap)
    nb = -(-s // w)
    pad = nb * w - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nb, w, hk, rep, hd)
    kb = k.reshape(b, nb, w, hk, hd)
    vb = v.reshape(b, nb, w, hk, hd)
    k_prev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    v_prev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kc = jnp.concatenate([k_prev, kb], axis=2)  # (b, nb, 2w, hk, hd)
    vc = jnp.concatenate([v_prev, vb], axis=2)
    sc = jnp.einsum("bnigrh,bnjgh->bngrij", qb, kc,
                    preferred_element_type=jnp.float32) * hd ** -0.5
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    i = jnp.arange(w)[:, None]
    j = jnp.arange(2 * w)[None, :]
    delta = i + w - j            # q_abs - k_abs
    rel_ok = (delta >= 0) & (delta < w)
    first_blk = (jnp.arange(nb) == 0)[:, None, None]
    from_prev = (j < w)[None, :, :] * jnp.ones((nb, w, 2 * w), bool)
    valid = rel_ok[None] & ~(first_blk & from_prev)
    # mask padded queries/keys at the tail
    qi_abs = jnp.arange(nb)[:, None] * w + jnp.arange(w)[None, :]
    kj_abs = (jnp.arange(nb)[:, None] - 1) * w + jnp.arange(2 * w)[None, :]
    valid = (valid & (qi_abs[:, :, None] < s) & (kj_abs[:, None, :] < s)
             & (kj_abs[:, None, :] >= 0))
    sc = jnp.where(valid[None, :, None, None], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1).astype(vc.dtype)
    out = jnp.einsum("bngrij,bnjgh->bnigrh", pr, vc)
    out = out.reshape(b, nb * w, hq, hd)[:, :s]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window=0, softcap=0.0):
    """One-token attention over a static-size cache.

    q: (B,1,Hq,hd); caches (B,T,Hk,hd); lengths (B,) = index of the current
    token (cache already contains it at position ``lengths``).
    """
    b, _, hq, hd = q.shape
    t, hk = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hk
    qg = q.reshape(b, 1, hk, rep, hd)
    sc = jnp.einsum("bsgrh,btgh->bgrst", qg, k_cache,
                    preferred_element_type=jnp.float32) * hd ** -0.5
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    kpos = jnp.arange(t)[None]
    ok = kpos <= lengths[:, None]
    if window > 0:
        ok &= kpos > (lengths[:, None] - window)
    sc = jnp.where(ok[:, None, None, None], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", pr, v_cache)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def flash_decode(q, k_cache, v_cache, lengths, *, mesh, seq_axis="data",
                 window=0):
    """Decode attention with the cache sequence dim sharded over the mesh.

    Implements flash-decoding: each shard computes a partial
    (max, numerator, denominator) over its cache slice; the partials are
    combined with psum after renormalizing — two tiny collectives instead
    of gathering a 500k-token cache.
    """
    b, _, hq, hd = q.shape
    t, hk = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hk
    n_shards = mesh.shape[seq_axis]

    def local(qv, kc, vc, ln):
        shard = lax.axis_index(seq_axis)
        t_loc = kc.shape[1]
        qg = qv.reshape(b, 1, hk, rep, hd)
        sc = jnp.einsum("bsgrh,btgh->bgrst", qg, kc,
                        preferred_element_type=jnp.float32) * hd ** -0.5
        kpos = shard * t_loc + jnp.arange(t_loc)[None]
        ok = kpos <= ln[:, None]
        if window > 0:
            ok &= kpos > (ln[:, None] - window)
        sc = jnp.where(ok[:, None, None, None], sc, NEG_INF)
        m = sc.max(axis=-1)                     # (b,hk,rep,1)
        p = jnp.exp(sc - m[..., None])
        den = p.sum(axis=-1)
        num = jnp.einsum("bgrst,btgh->bgrsh", p.astype(vc.dtype), vc,
                         preferred_element_type=jnp.float32)
        m_g = lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        den_g = lax.psum(den * corr, seq_axis)
        num_g = lax.psum(num * corr[..., None], seq_axis)
        out = num_g / jnp.maximum(den_g, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, 1, hq, hd)

    from repro.compat import shard_map
    fd = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, seq_axis, None, None),
                  P(None, seq_axis, None, None), P()),
        out_specs=P())
    return fd(q, k_cache, v_cache, lengths).astype(q.dtype)


def _pad_heads_even(q, k, v, hq, hk, mesh):
    """Expand GQA→MHA and zero-pad heads so they divide the model axis."""
    m = mesh.shape.get("model", 1) if mesh is not None else 1
    if m <= 1 or (hq % m == 0 and hk % m == 0 and hq == hk):
        if hq % m == 0 and hk % m == 0:
            return q, k, v, hq, hk
    rep = hq // hk
    if rep > 1 and (hk % m or hq % m):
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        hk = hq
    hpad = -(-hq // m) * m
    if hpad != hq:
        pad = [(0, 0), (0, 0), (0, hpad - hq), (0, 0)]
        q = jnp.pad(q, pad)
        if hk == hq:
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
            hk = hpad
        hq = hpad
    return q, k, v, hq, hk


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + cache).
# ---------------------------------------------------------------------------

def attention_specs(cfg: ArchConfig, desc: BlockDesc) -> dict[str, PSpec]:
    d, hq, hk = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    specs = {
        "wq": PSpec((d, hq * hd), ("embed", "heads")),
        "wk": PSpec((d, hk * hd), ("embed", "kv_heads")),
        "wv": PSpec((d, hk * hd), ("embed", "kv_heads")),
        "wo": PSpec((hq * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = PSpec((hq * hd,), ("heads",), init="zeros")
        specs["bk"] = PSpec((hk * hd,), ("kv_heads",), init="zeros")
        specs["bv"] = PSpec((hk * hd,), ("kv_heads",), init="zeros")
    return specs


def attention_apply(params, x, cfg: ArchConfig, desc: BlockDesc, *,
                    positions, mode: str = "train", cache=None,
                    lengths=None, mesh=None, seq_shard=False,
                    attn_impl: str = "flash"):
    """Returns (out, new_cache)."""
    b, s, d = x.shape
    hq, hk = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = _split_heads(q, hq, hd)
    k = _split_heads(k, hk, hd)
    v = _split_heads(v, hk, hd)
    cos, sin = rope_angles(positions, hd, desc.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    hq_real = hq
    k_real, v_real = k, v   # cache stores the un-padded GQA heads
    if mode == "decode" and not seq_shard:
        # even shardings only: the cache is a jit input
        q = constrain_bthd(q, mesh)
        k = constrain_bthd(k, mesh)
        v = constrain_bthd(v, mesh)
    elif mode in ("train", "prefill") and not seq_shard:
        # HC1 (EXPERIMENTS.md §Perf): when heads don't divide the model
        # axis, GSPMD either replicates attention (3.4× FLOPs) or triggers
        # "involuntary full rematerialization" resharding storms (34 s of
        # collectives/step on gemma3 train_4k).  Fix: expand GQA→MHA and
        # explicitly zero-pad heads to a multiple of the axis — even
        # sharding end-to-end; padded heads are dead lanes sliced off
        # after (≤2× attention-only FLOPs, −97% collective bytes).
        q, k, v, hq, hk = _pad_heads_even(q, k, v, hq, hk, mesh)
        q = constrain_bthd(q, mesh)
        k = constrain_bthd(k, mesh)
        v = constrain_bthd(v, mesh)

    new_cache = None
    if mode in ("train", "prefill"):
        k_pos = positions if positions.ndim == 2 else positions[None]
        if desc.window and cfg.causal:
            out = swa_attention(q, k, v, positions, k_pos,
                                window=desc.window,
                                softcap=cfg.logit_softcap)
        elif attn_impl == "chunked_q":
            out = chunked_q_attention(q, k, v, positions, k_pos,
                                      causal=cfg.causal,
                                      softcap=cfg.logit_softcap)
        elif attn_impl == "flash":
            out = flash_attention(q, k, v, positions, k_pos,
                                  causal=cfg.causal,
                                  softcap=cfg.logit_softcap)
        else:
            out = naive_attention(q, k, v, positions, k_pos,
                                  causal=cfg.causal,
                                  softcap=cfg.logit_softcap)
        if mode == "prefill":
            new_cache = {"k": k_real, "v": v_real}
    elif mode == "decode":
        # Write this token's k/v at per-sequence position `lengths`.
        def write(c, new, ndim3=True):
            def upd(cb, nb, ln):
                start = (ln,) + (0,) * (cb.ndim - 1)
                return lax.dynamic_update_slice(cb, nb, start)
            return jax.vmap(upd)(c, new, lengths)

        if "k_s" in cache:
            # int8 KV cache (HC2): per-token scales (a per-head scale
            # tensor would be model-axis-replicated when heads don't
            # divide the axis — measured +5 GB/device on qwen decode);
            # halves the resident cache; dequantization is a per-layer
            # transient.
            def quant(x):
                s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1),
                            keepdims=True) / 127.0
                s = jnp.maximum(s, 1e-8)
                return (jnp.clip(jnp.round(x.astype(jnp.float32) / s),
                                 -127, 127).astype(jnp.int8),
                        s.astype(jnp.float32))
            kq, ks = quant(k)
            vq, vs = quant(v)
            k_q = write(cache["k"], kq)
            v_q = write(cache["v"], vq)
            k_sc = write(cache["k_s"], ks)
            v_sc = write(cache["v_s"], vs)
            new_cache = {"k": k_q, "v": v_q, "k_s": k_sc, "v_s": v_sc}
            k_cache = (k_q.astype(cfg.activation_dtype)
                       * k_sc.astype(cfg.activation_dtype))
            v_cache = (v_q.astype(cfg.activation_dtype)
                       * v_sc.astype(cfg.activation_dtype))
        else:
            k_cache = write(cache["k"], k)
            v_cache = write(cache["v"], v)
            if not seq_shard:
                k_cache = constrain_bthd(k_cache, mesh)
                v_cache = constrain_bthd(v_cache, mesh)
            new_cache = {"k": k_cache, "v": v_cache}
        if seq_shard and mesh is not None:
            out = flash_decode(q, k_cache, v_cache, lengths, mesh=mesh,
                               window=desc.window)
        else:
            out = decode_attention(q, k_cache, v_cache, lengths,
                                   window=desc.window,
                                   softcap=cfg.logit_softcap)
    else:
        raise ValueError(mode)
    out = out[:, :, :hq_real]   # drop padded dead-lane heads
    out = out.reshape(b, s, hq_real * hd) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-style multi-head latent attention).
# ---------------------------------------------------------------------------

def mla_specs(cfg: ArchConfig) -> dict[str, PSpec]:
    d, h = cfg.d_model, cfg.n_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": PSpec((d, ql), ("embed", "q_lora")),
        "q_norm": PSpec((ql,), (None,), init="zeros"),
        "wq_b": PSpec((ql, h * (dn + dr)), ("q_lora", "heads")),
        "wkv_a": PSpec((d, kl + dr), ("embed", None)),
        "kv_norm": PSpec((kl,), (None,), init="zeros"),
        "wkv_b": PSpec((kl, h * (dn + dv)), ("kv_lora", "heads")),
        "wo": PSpec((h * dv, d), ("heads", "embed")),
    }


def mla_apply(params, x, cfg: ArchConfig, desc: BlockDesc, *, positions,
              mode="train", cache=None, lengths=None, mesh=None,
              seq_shard=False, attn_impl="flash"):
    b, s, d = x.shape
    h = cfg.n_heads
    kl = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (q @ params["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv_a = x @ params["wkv_a"]
    c_kv = rms_norm(kv_a[..., :kl], params["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., kl:]                      # (b, s, dr), shared heads
    cos, sin = rope_angles(positions, dr, desc.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]

    new_cache = None
    if mode in ("train", "prefill"):
        kv = (c_kv @ params["wkv_b"]).reshape(b, s, h, dn + dv)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, dr))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        # HC1: pad heads to divide the model axis (see attention_apply)
        qf, k, v, hp, _ = _pad_heads_even(qf, k, v, h, h, mesh)
        qf = constrain_bthd(qf, mesh)
        k = constrain_bthd(k, mesh)
        v = constrain_bthd(v, mesh)
        k_pos = positions
        if attn_impl == "flash":
            out = flash_attention(qf, k, v, positions, k_pos, causal=True)
        else:
            out = naive_attention(qf, k, v, positions, k_pos, causal=True)
        out = out[:, :, :h]
        if mode == "prefill":
            new_cache = {"ckv": c_kv, "krope": k_rope}
    else:
        # Absorbed decode: attend in the compressed latent space.
        # score = q_nope·W_uk^T·c_kv + q_rope·k_rope;  out = (p·c_kv)·W_uv.
        w_b = params["wkv_b"].reshape(kl, h, dn + dv)
        w_uk = w_b[..., :dn]                    # (kl, h, dn)
        w_uv = w_b[..., dn:]                    # (kl, h, dv)
        q_lat = jnp.einsum("bshn,khn->bshk", q_nope, w_uk)  # (b,1,h,kl)
        ckv_c, kr_c = cache["ckv"], cache["krope"]

        def upd(cb, nb, ln):
            return lax.dynamic_update_slice(cb, nb, (ln, 0))
        ckv_c = jax.vmap(upd)(ckv_c, c_kv, lengths)
        kr_c = jax.vmap(upd)(kr_c, k_rope, lengths)
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        t = ckv_c.shape[1]
        sc = (jnp.einsum("bshk,btk->bhst", q_lat, ckv_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", q_rope, kr_c,
                           preferred_element_type=jnp.float32))
        sc *= (dn + dr) ** -0.5
        kpos = jnp.arange(t)[None]
        ok = kpos <= lengths[:, None]
        sc = jnp.where(ok[:, None, None], sc, NEG_INF)
        pr = jax.nn.softmax(sc, axis=-1).astype(ckv_c.dtype)
        o_lat = jnp.einsum("bhst,btk->bshk", pr, ckv_c)   # (b,1,h,kl)
        out = jnp.einsum("bshk,khv->bshv", o_lat, w_uv)
    out = out.reshape(b, s, -1) @ params["wo"]
    return out, new_cache
