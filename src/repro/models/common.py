"""Shared model infrastructure: parameter specs with logical sharding axes,
norms, rotary embeddings, initializers.

Parameters are plain pytrees (nested dicts of ``jnp.ndarray``).  Their
sharding is described *once*, at spec level: every leaf is declared as a
:class:`PSpec` carrying its shape and a tuple of **logical axis names**
("embed", "mlp", "vocab", …).  ``sharding/rules.py`` maps logical names to
mesh axes.  Stacked (scanned) parameters get a leading "layers" axis added
by the stacking helpers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["PSpec", "init_params", "spec_axes", "stack_specs", "rms_norm",
           "layer_norm", "apply_rope", "rope_angles", "Initializer"]

Initializer = str  # "normal" | "zeros" | "ones" | "embed"


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Declarative parameter spec: shape + logical axes + initializer."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: Initializer = "normal"
    scale: float | None = None   # None → 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key: jax.Array, spec: PSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        return jax.random.normal(key, spec.shape, spec.dtype)
    # truncated-normal fan-in scaling (maxtext-style)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    return scale * jax.random.truncated_normal(
        key, -2.0, 2.0, spec.shape).astype(spec.dtype)


def init_params(key: jax.Array, specs) -> Any:
    """Materialize a pytree of PSpecs into parameters."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, PSpec))
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def spec_axes(specs) -> Any:
    """The parallel pytree of logical-axis tuples."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, PSpec))


def spec_shapes(specs) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda x: isinstance(x, PSpec))


def stack_specs(specs, n: int) -> Any:
    """Prepend a scanned "layers" axis of size n to every spec."""
    return jax.tree.map(
        lambda s: PSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                        s.scale, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, PSpec))


# ---------------------------------------------------------------------------
# Norms (computed in fp32, cast back).
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """(…,) int positions → cos/sin of shape (…, dim/2)."""
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, hd/2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)
