"""Mixture-of-Experts layer: top-k routing with capacity-based einsum
dispatch (the GShard/Switch dataflow — TPU-native: dispatch/combine are
dense contractions that SPMD-partition cleanly with experts sharded over
the ``model`` mesh axis).

Includes the production losses: load-balance auxiliary loss and router
z-loss.  ``olmoe`` (64e top-8) and ``llama4-scout`` (16e top-1 + shared
expert) both route through here.

GANAX analogy (DESIGN.md §Arch-applicability): tokens-per-expert is the
same "structured irregular work" shape as taps-per-phase; the capacity
schedule plays the role of the longest-first phase ordering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import PSpec
from repro.models.mlp import mlp_apply, mlp_specs

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ArchConfig) -> dict[str, PSpec]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    specs = {
        "router": PSpec((d, e), ("embed", None), scale=0.02),
        "wi": PSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "wg": PSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "wo": PSpec((e, f, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        shared = mlp_specs(cfg, "swiglu",
                           d_ff=cfg.expert_d_ff * cfg.n_shared_experts)
        specs.update({f"shared_{k}": v for k, v in shared.items()})
    return specs


DEFAULT_GROUP = 256


def moe_apply(params, x, cfg: ArchConfig, *, capacity_factor: float | None
              = None, group_size: int = DEFAULT_GROUP):
    """x: (B, S, D) → (y, aux).

    Tokens are routed within *groups* of ``group_size`` (GShard): the
    dispatch/combine contractions cost O(T·group_size·k·cf·D) — linear in
    total tokens — instead of the quadratic cost of a global capacity
    buffer.  Groups inherit the batch sharding (the group dim is a reshape
    of (batch, seq)), so routing is local to each data shard while expert
    FFNs stay expert-sharded over ``model``.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    t = b * s
    sg = min(group_size, t)
    assert t % sg == 0, (t, sg)
    g = t // sg
    xt = x.reshape(g, sg, d)

    logits = jnp.einsum("gsd,de->gse", xt,
                        params["router"].astype(x.dtype)
                        ).astype(jnp.float32)                  # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G,Sg,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(sg * k * cf / e))
    # Position of each (token, slot) in its expert's buffer, per group.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)      # (G,Sg,k,E)
    flat = onehot.reshape(g, sg * k, e)
    pos_in = (jnp.cumsum(flat, axis=1) - flat).reshape(g, sg, k, e)
    pos = (pos_in * onehot).sum(-1)                            # (G,Sg,k)
    keep = pos < capacity
    disp_k = (jax.nn.one_hot(gate_idx, e, dtype=x.dtype)[..., None]
              * jax.nn.one_hot(pos, capacity, dtype=x.dtype)[..., None, :]
              * keep[..., None, None].astype(x.dtype))        # (G,Sg,k,E,C)
    combine = (disp_k * gate_vals[..., None, None].astype(x.dtype)
               ).sum(axis=2)                                   # (G,Sg,E,C)
    disp = disp_k.sum(axis=2)                                  # (G,Sg,E,C)

    xe = jnp.einsum("gsec,gsd->gecd", disp, xt)                # (G,E,C,D)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["wg"])) * \
        jnp.einsum("gecd,edf->gecf", xe, params["wi"])
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])         # (G,E,C,D)
    y = jnp.einsum("gsec,gecd->gsd", combine, ye)

    if cfg.n_shared_experts:
        shared = {k_[7:]: v for k_, v in params.items()
                  if k_.startswith("shared_")}
        y = y + mlp_apply(shared, xt.reshape(t, d), "swiglu").reshape(
            g, sg, d)

    # aux losses (fp32)
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = (jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
          .sum(axis=(0, 1, 2)) / (t * k))                      # frac/expert
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance_loss": load_balance, "router_z_loss": z_loss,
           "expert_load": ce}
    return y.reshape(b, s, d), aux
