"""Executable GAN models (the paper's Table I workloads) on GANAX ops.

Every (transposed) convolution goes through the unified dispatch layer
(`core.dataflow`): generators run the paper's MIMD-SIMD dataflow for
their transposed convs, discriminators run plain convolutions through the
same unified op (the SIMD mode).  The execution path — Pallas kernel on
TPU, interpret-mode kernel, pure-JAX polyphase, or the zero-insertion
baseline — is selected by a single :class:`~repro.core.dataflow
.DataflowPolicy`: set ``GanConfig.backend`` explicitly, or leave it
``None`` and the legacy ``dataflow``/``use_pallas`` fields are interpreted
by ``DataflowPolicy.from_legacy`` (their meaning lives in
``core/dataflow.py``, not here; they are deprecated — ``backend=`` is
the supported knob).  All paths are differentiable — the dispatch
layer's custom VJP re-enters the unified kernel for the backward pass —
so Pallas-backed configs train end-to-end.

Bias and activation are **fused epilogues**: every conv layer passes an
:class:`~repro.core.dataflow.Epilogue` (and its bias vector) into the
unified op instead of applying ``+ b`` / relu / tanh / leaky-relu as
separate post-ops, so the kernel backends never round-trip the raw
accumulator through HBM between a layer and its activation.
:func:`generator_epilogues` / :func:`discriminator_epilogues` are the
single source of truth for the per-layer specs — the autotuner's plan
keys (``repro.tune.zoo``) are built from the same helpers, so
``backend="auto"`` tunes exactly the fused op the model dispatches.

Execution is **ahead-of-time compiled**: ``generator_apply`` /
``discriminator_apply`` are thin legacy-compatible wrappers over cached
:class:`repro.program.Program` objects — the config → policy →
epilogue → plan walk runs once per (config, policy) at program build,
and the per-call path just replays the frozen
:class:`~repro.program.LayerExec` records.  New code should build a
``Program`` directly (``Program.build(cfg, batch, role)``); these
wrappers keep the historic signatures working.

These power the GAN training examples, the serving engine
(`serve.gan`), and the wall-clock microbenchmarks (GANAX dataflow vs
zero-insertion baseline on identical topologies).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.gans import GAN_MODELS
from repro.core.analytical import ConvLayer
from repro.core.dataflow import DataflowPolicy, Epilogue
from repro.models.common import PSpec, init_params

__all__ = ["GanConfig", "generator_specs", "discriminator_specs",
           "init_gan", "generator_apply", "discriminator_apply",
           "generator_epilogues", "discriminator_epilogues",
           "bce_with_logits", "gan_losses"]

# The discriminator's LeakyReLU slope (DCGAN convention, used by every
# Table-I discriminator).
LEAKY_SLOPE = 0.2


@dataclasses.dataclass(frozen=True)
class GanConfig:
    name: str
    z_dim: int = 100
    dataflow: str = "ganax"     # legacy: "ganax" | "zero_insert"
    use_pallas: bool = False    # legacy: Pallas kernel vs pure-JAX
    channel_scale: float = 1.0  # shrink channels for CPU-sized runs
    # Explicit DataflowPolicy backend override: a registered backend
    # name, the "pallas" preference, or "auto" (measured per-layer plans
    # from the repro.tune planner, heuristic fallback on a plan miss).
    backend: str | None = None
    # (data, model) device mesh programs built from this config freeze
    # by default (see ProgramSpec.build); None = single-device.  A
    # tuple so the config stays hashable for the program cache.
    mesh: tuple[int, int] | None = None
    # Storage precision of programs built from this config: "float32",
    # "bfloat16", or "float16" (aliases f32/bf16/f16 accepted and
    # canonicalized, keeping the config hashable).  Accumulation is
    # always float32 — see repro.quant.  Parameters themselves stay in
    # whatever dtype the optimizer holds (f32 from init_gan): programs
    # cast at use, so mixed-precision training needs no config beyond
    # this field.
    dtype: str = "float32"

    def __post_init__(self):
        from repro.quant.precision import canonical_dtype
        object.__setattr__(self, "dtype", canonical_dtype(self.dtype))

    @property
    def policy(self) -> DataflowPolicy:
        if self.backend is not None:
            return DataflowPolicy(backend=self.backend)
        return DataflowPolicy.from_legacy(dataflow=self.dataflow,
                                          use_pallas=self.use_pallas)

    @property
    def layers(self) -> tuple[list[ConvLayer], list[ConvLayer]]:
        g, d = GAN_MODELS[self.name]
        if self.channel_scale != 1.0:
            def shrink(l: ConvLayer) -> ConvLayer:
                c_in = max(1, int(l.cin * self.channel_scale)) \
                    if l.cin > 3 else l.cin
                c_out = max(1, int(l.cout * self.channel_scale)) \
                    if l.cout > 3 else l.cout
                return dataclasses.replace(l, cin=c_in, cout=c_out)
            g = [shrink(l) for l in g]
            d = [shrink(l) for l in d]
        return g, d


def _conv_specs(layers: Sequence[ConvLayer], prefix: str) -> dict:
    specs = {}
    for i, l in enumerate(layers):
        fan_in = int(jnp.prod(jnp.asarray(l.kernel))) * l.cin
        specs[f"{prefix}{i}_w"] = PSpec(
            tuple(l.kernel) + (l.cin, l.cout),
            (None,) * len(l.kernel) + ("conv_in", "conv_out"),
            scale=fan_in ** -0.5)   # no batch-norm → fan-in init
        specs[f"{prefix}{i}_b"] = PSpec((l.cout,), ("conv_out",),
                                        init="zeros")
    return specs


def generator_specs(cfg: GanConfig) -> dict:
    g_layers, _ = cfg.layers
    first = g_layers[0]
    proj_dim = int(jnp.prod(jnp.asarray(first.in_spatial))) * first.cin
    specs = {"proj_w": PSpec((cfg.z_dim, proj_dim), (None, "mlp"),
                             scale=0.02),
             "proj_b": PSpec((proj_dim,), ("mlp",), init="zeros")}
    specs.update(_conv_specs(g_layers, "t"))
    return specs


def discriminator_specs(cfg: GanConfig) -> dict:
    _, d_layers = cfg.layers
    return _conv_specs(d_layers, "c")


def init_gan(cfg: GanConfig, key: jax.Array):
    kg, kd = jax.random.split(key)
    return (init_params(kg, generator_specs(cfg)),
            init_params(kd, discriminator_specs(cfg)))


def generator_epilogues(g_layers: Sequence[ConvLayer]) -> list[Epilogue]:
    """Per-layer fused epilogues of a Table-I generator: bias + ReLU on
    every hidden layer, bias + tanh on the image-producing last one."""
    last = len(g_layers) - 1
    return [Epilogue(bias=True,
                     activation="tanh" if i == last else "relu")
            for i in range(len(g_layers))]


def discriminator_epilogues(d_layers: Sequence[ConvLayer]
                            ) -> list[Epilogue]:
    """Per-layer fused epilogues of a Table-I discriminator: bias +
    LeakyReLU on every hidden layer, bias only on the logits layer."""
    last = len(d_layers) - 1
    return [Epilogue(bias=True,
                     activation="none" if i == last else "leaky_relu",
                     leaky_slope=LEAKY_SLOPE)
            for i in range(len(d_layers))]


@functools.lru_cache(maxsize=64)
def _cached_program(cfg: GanConfig, policy: DataflowPolicy, role: str,
                    batch: int):
    """One frozen Program per (config, policy, role) — the legacy apply
    functions are thin wrappers over these.  ``batch`` only matters for
    ``backend="auto"`` plan keys; concrete policies resolve
    batch-independently, so they cache under batch=0."""
    from repro.program import Program
    return Program.build(cfg, max(batch, 1), role, policy=policy,
                         differentiable=policy.differentiable)


def _program_for(cfg: GanConfig, policy: DataflowPolicy | None,
                 role: str, batch: int):
    policy = policy or cfg.policy
    if policy.backend == "auto":
        # auto resolution is a planner snapshot: rebuild per call (cheap
        # — lookups only, never measures) so fresh plans take effect,
        # exactly like the per-dispatch consult this API replaces
        from repro.program import Program
        return Program.build(cfg, batch, role, policy=policy,
                             differentiable=policy.differentiable)
    return _cached_program(cfg, policy, role, 0)


def generator_apply(params, z, cfg: GanConfig,
                    policy: DataflowPolicy | None = None):
    """z (B, z_dim) → image (B, *spatial, C).

    Legacy-compatible wrapper over a cached ahead-of-time
    :class:`repro.program.Program`: the layer walk (config → policy →
    epilogues → plans) runs once at program build, not per call.  Every
    conv layer's bias+activation runs as a fused epilogue inside the
    unified op (only the z-projection MLP keeps its own bias/ReLU)."""
    prog = _program_for(cfg, policy, "generator", int(z.shape[0]))
    return prog.forward(params, z)


def discriminator_apply(params, img, cfg: GanConfig,
                        policy: DataflowPolicy | None = None):
    """img (B, *spatial, C) → logits (B,).  Same program-backed wrapper
    as :func:`generator_apply`; bias + LeakyReLU run as fused epilogues
    inside the unified conv op."""
    prog = _program_for(cfg, policy, "discriminator", int(img.shape[0]))
    return prog.forward(params, img)


def bce_with_logits(logits, target):
    """Numerically stable binary cross-entropy on logits."""
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * target +
        jnp.log1p(jnp.exp(-jnp.abs(logits))))


def gan_losses(g_params, d_params, z, real, cfg: GanConfig,
               programs=None):
    """Non-saturating GAN losses (generator, discriminator).

    ``programs`` — an optional ``(generator Program, discriminator
    Program)`` pair — skips even the cached-program lookup: the train
    loop builds both once and threads them here."""
    if programs is not None:
        g_prog, d_prog = programs
        fake = g_prog.forward(g_params, z)
        d_fake = d_prog.forward(d_params, fake)
        d_real = d_prog.forward(d_params, real)
    else:
        fake = generator_apply(g_params, z, cfg)
        d_fake = discriminator_apply(d_params, fake, cfg)
        d_real = discriminator_apply(d_params, real, cfg)
    d_loss = bce_with_logits(d_real, 1.0) + bce_with_logits(d_fake, 0.0)
    g_loss = bce_with_logits(d_fake, 1.0)
    return g_loss, d_loss, fake
