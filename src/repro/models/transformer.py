"""Model assembly: decoder LMs, encoder, VLM backbone — all families.

One code path serves every assigned architecture.  Depth is expressed as
*segments* (``ArchConfig.layer_segments``): each segment is a
``lax.scan`` over stacked per-layer parameters (compile time and HLO size
stay flat in depth — a 64-layer 512-device train step lowers in seconds),
with the blocks inside a segment's repeating pattern unrolled (this is how
gemma3's 5:1 local:global and hymba's sparse-global patterns keep their
*true* sub-quadratic FLOPs instead of being masked-out full attention).

Entry points:
  * ``model_specs(cfg)``      → PSpec pytree (shapes + logical sharding axes)
  * ``init(cfg, key)``        → params
  * ``forward(params, batch, cfg, mode=...)`` → logits (+cache at prefill)
  * ``loss_fn`` / ``decode_step`` / ``init_cache``
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, BlockDesc
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (PSpec, init_params, rms_norm, spec_axes,
                                 stack_specs)
from repro.models.mlp import mlp_apply, mlp_specs

__all__ = ["RunFlags", "model_specs", "model_axes", "init", "forward",
           "loss_fn", "decode_step", "init_cache", "count_params",
           "model_flops_per_token"]


@dataclasses.dataclass(frozen=True)
class RunFlags:
    """Runtime/performance knobs threaded through the forward pass."""
    attn_impl: str = "flash"          # "flash" | "naive"
    remat: bool = True
    remat_policy: str = "nothing"     # "nothing" | "dots"
    seq_shard_decode: bool = False    # flash-decode over data-sharded cache
    mesh: Any = None
    scan_layers: bool = True


# ---------------------------------------------------------------------------
# Specs.
# ---------------------------------------------------------------------------

def _block_specs(cfg: ArchConfig, desc: BlockDesc) -> dict[str, Any]:
    d = cfg.d_model
    specs: dict[str, Any] = {
        "ln_mix": PSpec((d,), (None,), init="zeros"),
    }
    if desc.mixer == "attn":
        specs["attn"] = attn_mod.attention_specs(cfg, desc)
    elif desc.mixer == "mla":
        specs["attn"] = attn_mod.mla_specs(cfg)
    elif desc.mixer == "ssm":
        specs["ssm"] = ssm_mod.ssm_specs(cfg)
    elif desc.mixer == "hybrid":
        specs["attn"] = attn_mod.attention_specs(cfg, desc)
        specs["ssm"] = ssm_mod.ssm_specs(cfg)
    else:
        raise ValueError(desc.mixer)
    if desc.mlp == "moe":
        specs["ln_mlp"] = PSpec((d,), (None,), init="zeros")
        specs["mlp"] = moe_mod.moe_specs(cfg)
    elif desc.mlp != "none":
        specs["ln_mlp"] = PSpec((d,), (None,), init="zeros")
        specs["mlp"] = mlp_specs(cfg, desc.mlp)
    return specs


def model_specs(cfg: ArchConfig) -> dict[str, Any]:
    d = cfg.d_model
    specs: dict[str, Any] = {
        "embed": PSpec((cfg.padded_vocab, d), ("vocab", "embed"),
                       init="embed", scale=1.0),
        "final_norm": PSpec((d,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["head"] = PSpec((d, cfg.padded_vocab), ("embed", "vocab"))
    if cfg.family == "vlm":
        specs["img_proj"] = PSpec((cfg.frontend_dim, d), (None, "embed"))
    if cfg.family == "encoder":
        specs["frontend_proj"] = PSpec((cfg.frontend_dim, d),
                                       (None, "embed"))
        # sized for the largest assigned encode shape (prefill_32k)
        specs["pos_embed"] = PSpec((32768, d), (None, "embed"), scale=0.02)
    segs = {}
    for si, (descs, rep) in enumerate(cfg.layer_segments()):
        seg = {f"pos{di}": _block_specs(cfg, desc)
               for di, desc in enumerate(descs)}
        segs[f"seg{si}"] = stack_specs(seg, rep)
    specs["segments"] = segs
    return specs


def model_axes(cfg: ArchConfig):
    return spec_axes(model_specs(cfg))


def init(cfg: ArchConfig, key: jax.Array):
    return init_params(key, model_specs(cfg))


def count_params(cfg: ArchConfig) -> int:
    specs = model_specs(cfg)
    leaves = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, PSpec))
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= int(d)    # python ints: no int32 overflow at 7B params
        total += n
    return total


def model_flops_per_token(cfg: ArchConfig) -> float:
    """MODEL_FLOPS/token = 6·N (dense) or 6·N_active (MoE), for §Roofline."""
    specs = model_specs(cfg)
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, PSpec))[0]:
        n = 1
        for dim in s.shape:
            n *= dim
        keys = "/".join(str(p) for p in path)
        if cfg.moe and ("'wi'" in keys or "'wg'" in keys or "'wo'" in keys) \
                and "'mlp'" in keys and "shared" not in keys:
            # routed experts: only top_k of n_experts active per token
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return 6.0 * total


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------

def _block_apply(params, x, cfg, desc, *, positions, mode, cache, lengths,
                 flags: RunFlags):
    new_cache = {}
    aux = {"load_balance_loss": jnp.zeros((), jnp.float32),
           "router_z_loss": jnp.zeros((), jnp.float32)}
    h = rms_norm(x, params["ln_mix"], cfg.norm_eps)
    seq_shard = flags.seq_shard_decode and desc.window == 0
    if desc.mixer in ("attn", "mla"):
        fn = attn_mod.mla_apply if desc.mixer == "mla" else \
            attn_mod.attention_apply
        out, c = fn(params["attn"], h, cfg, desc, positions=positions,
                    mode=mode, cache=None if cache is None else
                    cache.get("attn"), lengths=lengths, mesh=flags.mesh,
                    seq_shard=seq_shard, attn_impl=flags.attn_impl)
        if c is not None:
            new_cache["attn"] = c
    elif desc.mixer == "ssm":
        if mode == "decode":
            out, c = ssm_mod.ssm_decode_step(params["ssm"], h, cfg,
                                             cache["ssm"])
        else:
            out, c = ssm_mod.ssm_apply(params["ssm"], h, cfg, mode=mode)
        if c is not None:
            new_cache["ssm"] = c
    elif desc.mixer == "hybrid":
        a_out, ac = attn_mod.attention_apply(
            params["attn"], h, cfg, desc, positions=positions, mode=mode,
            cache=None if cache is None else cache.get("attn"),
            lengths=lengths, mesh=flags.mesh, seq_shard=seq_shard,
            attn_impl=flags.attn_impl)
        if mode == "decode":
            s_out, sc = ssm_mod.ssm_decode_step(params["ssm"], h, cfg,
                                                cache["ssm"])
        else:
            s_out, sc = ssm_mod.ssm_apply(params["ssm"], h, cfg, mode=mode)
        out = 0.5 * (a_out + s_out)
        if ac is not None:
            new_cache["attn"] = ac
        if sc is not None:
            new_cache["ssm"] = sc
    else:
        raise ValueError(desc.mixer)
    x = x + out
    if desc.mlp == "moe":
        h = rms_norm(x, params["ln_mlp"], cfg.norm_eps)
        y, moe_aux = moe_mod.moe_apply(params["mlp"], h, cfg)
        aux["load_balance_loss"] += moe_aux["load_balance_loss"]
        aux["router_z_loss"] += moe_aux["router_z_loss"]
        x = x + y
    elif desc.mlp != "none":
        h = rms_norm(x, params["ln_mlp"], cfg.norm_eps)
        x = x + mlp_apply(params["mlp"], h, desc.mlp)
    return x, new_cache, aux


def _embed_in(params, batch, cfg: ArchConfig):
    dt = cfg.activation_dtype
    if cfg.family == "encoder":
        feats = batch["features"].astype(dt)
        x = feats @ params["frontend_proj"].astype(dt)
        s = x.shape[1]
        return x + params["pos_embed"][:s].astype(dt)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(dt) @ params["img_proj"].astype(dt)
        x = jnp.concatenate([img, x[:, cfg.img_tokens:]], axis=1) \
            if x.shape[1] >= cfg.img_tokens else x
    return x


def _logits(params, x, cfg: ArchConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ head.astype(x.dtype)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:  # mask padding columns
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def _cast_params(params, dt):
    """Mixed precision: compute in the activation dtype (norm internals and
    SSM decay math re-upcast to fp32 where it matters)."""
    return jax.tree.map(
        lambda a: a.astype(dt) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, params)


def forward(params, batch, cfg: ArchConfig, *, mode: str = "train",
            cache=None, lengths=None, flags: RunFlags = RunFlags(),
            last_logit_only: bool = False):
    """Returns (logits, new_cache, aux); new_cache is None in train mode.

    ``last_logit_only``: prefill only needs the final position's logits —
    computing the full (S, vocab) matmul wastes ~2·S·d·V FLOPs (measured:
    ~half of qwen1.5-32b prefill_32k compute, EXPERIMENTS.md §Perf HC3).
    """
    params = _cast_params(params, cfg.activation_dtype)
    x = _embed_in(params, batch, cfg)
    b, s, _ = x.shape
    if mode == "decode":
        positions = lengths[:, None]
    else:
        positions = batch.get("positions") if isinstance(batch, dict) else None
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    aux_sum = {"load_balance_loss": jnp.zeros((), jnp.float32),
               "router_z_loss": jnp.zeros((), jnp.float32)}
    new_cache = {}
    for si, (descs, rep) in enumerate(cfg.layer_segments()):
        seg_params = params["segments"][f"seg{si}"]
        seg_cache = None if cache is None else cache[f"seg{si}"]

        def body(xc, layer_in, descs=descs):
            xx = xc
            lp, lc = layer_in
            outs_cache = {}
            aux_l = {"load_balance_loss": jnp.zeros((), jnp.float32),
                     "router_z_loss": jnp.zeros((), jnp.float32)}
            for di, desc in enumerate(descs):
                blk_cache = None if lc is None else lc[f"pos{di}"]
                xx, nc, aux = _block_apply(
                    lp[f"pos{di}"], xx, cfg, desc, positions=positions,
                    mode=mode, cache=blk_cache, lengths=lengths,
                    flags=flags)
                outs_cache[f"pos{di}"] = nc
                aux_l = {k: aux_l[k] + aux[k] for k in aux_l}
            return xx, (outs_cache, aux_l)

        if flags.remat and mode == "train":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if flags.remat_policy == "dots" else None)
            body = jax.checkpoint(body, policy=policy,
                                  prevent_cse=flags.scan_layers)

        if flags.scan_layers and mode == "decode":
            # Cache lives in the scan CARRY and is updated in place with
            # dynamic_update_index — XLA aliases the whole buffer through
            # the loop (with xs→ys the cache would be copied: +2× temp).
            def dbody(carry, lp, descs=descs):
                xx, cache_st, li = carry
                lc = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, li, 0,
                                                       keepdims=False),
                    cache_st)
                xx, (nc, aux_l) = body(xx, (lp, lc))
                cache_st = jax.tree.map(
                    lambda a, v: lax.dynamic_update_index_in_dim(
                        a, v.astype(a.dtype), li, 0), cache_st, nc)
                return (xx, cache_st, li + 1), aux_l
            (x, seg_new_cache, _), aux_seg = lax.scan(
                dbody, (x, seg_cache, jnp.zeros((), jnp.int32)),
                seg_params)
            aux_sum = {k: aux_sum[k] + aux_seg[k].sum() for k in aux_sum}
        elif flags.scan_layers:
            xs = (seg_params, seg_cache)
            x, (seg_new_cache, aux_seg) = lax.scan(body, x, xs)
            aux_sum = {k: aux_sum[k] + aux_seg[k].sum() for k in aux_sum}
        else:
            seg_new_cache = None
            for li in range(rep):
                lp = jax.tree.map(lambda a: a[li], seg_params)
                lc = None if seg_cache is None else jax.tree.map(
                    lambda a: a[li], seg_cache)
                x, (nc, aux_l) = body(x, (lp, lc))
                aux_sum = {k: aux_sum[k] + aux_l[k] for k in aux_sum}
                if nc:
                    if seg_new_cache is None:
                        seg_new_cache = jax.tree.map(
                            lambda a: jnp.zeros((rep,) + a.shape, a.dtype),
                            nc)
                    seg_new_cache = jax.tree.map(
                        lambda acc, v: acc.at[li].set(v), seg_new_cache, nc)
        new_cache[f"seg{si}"] = seg_new_cache
    if last_logit_only:
        x = x[:, -1:]
    logits = _logits(params, x, cfg)
    return logits, (new_cache if mode in ("prefill", "decode") else None), \
        aux_sum


# ---------------------------------------------------------------------------
# Loss / decode.
# ---------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ArchConfig, flags: RunFlags = RunFlags(),
            aux_weight: float = 0.01, z_weight: float = 1e-3):
    """Next-token (causal) or masked-frame (encoder) cross-entropy.

    The label-logit term uses a one-hot contraction so the vocab dimension
    can stay model-sharded end-to-end (no gather across shards).
    """
    logits, _, aux = forward(params, batch, cfg, mode="train", flags=flags)
    logits = logits.astype(jnp.float32)
    if cfg.family == "encoder":
        labels = batch["labels"]
        weights = batch.get("label_mask")
        if weights is None:
            weights = jnp.ones_like(labels, jnp.float32)
    else:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        weights = jnp.pad(
            jnp.ones_like(labels[:, :-1], jnp.float32), ((0, 0), (0, 1)))
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.einsum("bsv,bsv->bs", logits,
                     jax.nn.one_hot(labels, cfg.padded_vocab,
                                    dtype=jnp.float32))
    nll = (lse - lab) * weights
    loss = nll.sum() / jnp.maximum(weights.sum(), 1.0)
    total = loss + aux_weight * aux["load_balance_loss"] + \
        z_weight * aux["router_z_loss"]
    metrics = {"loss": loss, "aux_lb": aux["load_balance_loss"],
               "aux_z": aux["router_z_loss"],
               "tokens": weights.sum()}
    return total, metrics


def decode_step(params, cache, tokens, lengths, cfg: ArchConfig,
                flags: RunFlags = RunFlags()):
    """One decoding step.  tokens (B,1) → (logits (B,vocab), new_cache)."""
    logits, new_cache, _ = forward(params, {"tokens": tokens}, cfg,
                                   mode="decode", cache=cache,
                                   lengths=lengths, flags=flags)
    return logits[:, -1], new_cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None, kv_dtype: str = "bf16") -> dict:
    """Zero-initialized cache pytree matching the segment structure.

    ``kv_dtype="int8"``: quantized attention cache with per-(token, head)
    fp32 scales (×2 less resident HBM; see EXPERIMENTS.md §Perf HC2).
    """
    dt = dtype or cfg.activation_dtype
    hd = cfg.resolved_head_dim
    cache: dict[str, Any] = {}
    for si, (descs, rep) in enumerate(cfg.layer_segments()):
        seg = {}
        for di, desc in enumerate(descs):
            blk = {}
            if desc.mixer == "attn" or desc.mixer == "hybrid":
                # NOTE: local (windowed) layers could use a ring buffer of
                # size `window`; we keep absolute-position full-length
                # caches for simplicity and track the ring-buffer variant
                # as a memory-term optimization (EXPERIMENTS.md §Perf).
                kv_dt = jnp.int8 if kv_dtype == "int8" else dt
                blk["attn"] = {
                    "k": jnp.zeros((rep, batch, max_len, cfg.n_kv_heads,
                                    hd), kv_dt),
                    "v": jnp.zeros((rep, batch, max_len, cfg.n_kv_heads,
                                    hd), kv_dt),
                }
                if kv_dtype == "int8":
                    blk["attn"]["k_s"] = jnp.zeros(
                        (rep, batch, max_len, 1, 1), jnp.float32)
                    blk["attn"]["v_s"] = jnp.zeros(
                        (rep, batch, max_len, 1, 1), jnp.float32)
            if desc.mixer == "mla":
                blk["attn"] = {
                    "ckv": jnp.zeros((rep, batch, max_len,
                                      cfg.kv_lora_rank), dt),
                    "krope": jnp.zeros((rep, batch, max_len,
                                        cfg.qk_rope_head_dim), dt),
                }
            if desc.mixer in ("ssm", "hybrid"):
                di_, h, p, g, n, conv_dim = ssm_mod._dims(cfg)
                blk["ssm"] = {
                    "h": jnp.zeros((rep, batch, h, p, n), jnp.float32),
                    "conv": jnp.zeros((rep, batch, cfg.ssm_conv - 1,
                                       conv_dim), dt),
                }
            seg[f"pos{di}"] = blk
        cache[f"seg{si}"] = seg
    return cache
