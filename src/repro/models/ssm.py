"""Mamba2 mixer: chunked SSD (state-space duality) + single-step decode.

The SSD computation follows the Mamba2 paper's block decomposition: the
sequence is split into chunks of ``Q`` tokens; within a chunk the recurrence
is evaluated as a (masked, decay-weighted) attention-like contraction
(quadratic in Q, MXU-friendly); across chunks a ``lax.scan`` carries the
(B, H, P, N) state — linear in sequence length, which is what makes the
``long_500k`` shape runnable for SSM/hybrid archs.

Decode is the O(1) recurrent update: ``h ← h·exp(dA) + dt·x⊗B``,
``y = C·h + D·x``, with a (conv_width-1)-deep rolling buffer for the causal
depthwise conv.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.common import PSpec, rms_norm

__all__ = ["ssm_specs", "ssm_apply", "ssm_decode_step"]

CHUNK = 256


def _dims(cfg: ArchConfig):
    di = cfg.ssm_d_inner
    h = cfg.ssm_heads
    p = cfg.ssm_head_dim
    g = cfg.ssm_groups
    n = cfg.ssm_state
    conv_dim = di + 2 * g * n
    return di, h, p, g, n, conv_dim


def ssm_specs(cfg: ArchConfig) -> dict[str, PSpec]:
    d = cfg.d_model
    di, h, p, g, n, conv_dim = _dims(cfg)
    w = cfg.ssm_conv
    return {
        "in_proj": PSpec((d, 2 * di + 2 * g * n + h), ("embed", "ssm_inner")),
        "conv_w": PSpec((w, conv_dim), (None, "ssm_conv_dim")),
        "conv_b": PSpec((conv_dim,), ("ssm_conv_dim",), init="zeros"),
        "A_log": PSpec((h,), ("ssm_heads",), init="ones"),
        "D": PSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": PSpec((h,), ("ssm_heads",), init="zeros"),
        "norm": PSpec((di,), ("ssm_inner",), init="zeros"),
        "out_proj": PSpec((di, d), ("ssm_inner", "embed")),
    }


def _split_proj(zxbcdt, cfg):
    di, h, p, g, n, _ = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, cache=None):
    """Depthwise causal conv1d.  xbc (B, L, C); conv_w (W, C).

    Returns (out, new_cache) where cache holds the last W-1 inputs.
    """
    w = conv_w.shape[0]
    if cache is not None:
        xfull = jnp.concatenate([cache, xbc], axis=1)
        new_cache = xfull[:, -(w - 1):]
    else:
        xfull = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
        new_cache = xfull[:, -(w - 1):]
    out = lax.conv_general_dilated(
        xfull, conv_w[:, None, :].astype(xfull.dtype), window_strides=(1,),
        padding="VALID", dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=xbc.shape[-1])
    return jax.nn.silu(out + conv_b.astype(out.dtype)), new_cache


def _ssd_chunked(x, dt, A, B, C, D, h0=None, chunk=CHUNK):
    """Chunked SSD core.

    x (B,L,H,P); dt (B,L,H); A (H,) (negative); B,C (B,L,G,N); D (H,).
    Returns (y (B,L,H,P), h_final (B,H,P,N)).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    r = h // g
    q = min(chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def chunkify(t):
        return t.reshape((b, nc, q) + t.shape[2:]).swapaxes(0, 1)

    xs = (chunkify(x * dt[..., None]), chunkify(dt), chunkify(B),
          chunkify(C))
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    tri = jnp.tril(jnp.ones((q, q), bool))

    def body(hprev, blk):
        xdt, dtc, Bc, Cc = blk           # (B,Q,...)
        dA = dtc.astype(jnp.float32) * A  # (B,Q,H) negative
        cum = jnp.cumsum(dA, axis=1)      # inclusive
        # intra-chunk; mask BEFORE exp (upper-triangle entries are positive
        # and would overflow, poisoning gradients through the where).
        seg = cum[:, :, None, :] - cum[:, None, :, :]        # (B,i,j,H)
        seg = jnp.where(tri[None, :, :, None], seg, -jnp.inf)
        Lm = jnp.exp(seg)
        scores = jnp.einsum("bign,bjgn->bgij", Cc, Bc,
                            preferred_element_type=jnp.float32)
        Lg = Lm.reshape(b, q, q, g, r)
        xg = xdt.reshape(b, q, g, r, p)
        y_in = jnp.einsum("bgij,bijgr,bjgrp->bigrp", scores, Lg, xg,
                          preferred_element_type=jnp.float32)
        # inbound state contribution
        hg = hprev.reshape(b, g, r, p, n)
        y_st = jnp.einsum("bign,bgrpn->bigrp", Cc, hg,
                          preferred_element_type=jnp.float32)
        y_st = y_st * jnp.exp(cum).reshape(b, q, g, r)[..., None]
        y = (y_in + y_st).reshape(b, q, h, p)
        # state update
        decay_end = jnp.exp(cum[:, -1:, :] - cum)            # (B,Q,H)
        dxg = (xdt * decay_end[..., None]).reshape(b, q, g, r, p)
        h_add = jnp.einsum("bjgrp,bjgn->bgrpn", dxg, Bc,
                           preferred_element_type=jnp.float32)
        h_new = hprev * jnp.exp(cum[:, -1, :])[:, :, None, None] + \
            h_add.reshape(b, h, p, n)
        return h_new, y

    h_final, ys = lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, nc * q, h, p)[:, :l]
    y = y + x[:, :l] * D[:, None]
    return y.astype(x.dtype), h_final


def ssm_apply(params, x, cfg: ArchConfig, *, mode="train", cache=None):
    """Full-sequence Mamba2 mixer.  Returns (y, new_cache)."""
    b, l, d = x.shape
    di, h, p, g, n, conv_dim = _dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc, conv_cache = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xi, B, C = jnp.split(xbc, [di, di + g * n], axis=-1)
    xi = xi.reshape(b, l, h, p)
    B = B.reshape(b, l, g, n)
    C = C.reshape(b, l, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"])               # (B,L,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))     # (H,)
    y, h_final = _ssd_chunked(xi, dt, A, B, C,
                              params["D"].astype(jnp.float32))
    y = y.reshape(b, l, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    new_cache = None
    if mode == "prefill":
        new_cache = {"h": h_final.astype(jnp.float32),
                     "conv": conv_cache}
    return out, new_cache


def ssm_decode_step(params, x, cfg: ArchConfig, cache):
    """Single-token recurrent update.  x (B,1,D)."""
    b, _, d = x.shape
    di, h, p, g, n, conv_dim = _dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc, conv_cache = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   cache=cache["conv"])
    xi, B, C = jnp.split(xbc, [di, di + g * n], axis=-1)
    xi = xi.reshape(b, h, p)
    B = B.reshape(b, g, n)
    C = C.reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"])[:, 0]         # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                   # (B,H)
    r = h // g
    hprev = cache["h"]                                     # (B,H,P,N)
    xdt = (xi * dt[..., None]).reshape(b, g, r, p)
    h_add = jnp.einsum("bgrp,bgn->bgrpn", xdt.astype(jnp.float32),
                       B.astype(jnp.float32)).reshape(b, h, p, n)
    h_new = hprev * dA[:, :, None, None] + h_add
    hg = h_new.reshape(b, g, r, p, n)
    y = jnp.einsum("bgn,bgrpn->bgrp", C.astype(jnp.float32), hg)
    y = y.reshape(b, h, p) + xi.astype(jnp.float32) * \
        params["D"][:, None].astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"h": h_new, "conv": conv_cache}
