"""Gated MLP variants (SwiGLU / GeGLU / plain GELU)."""

from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.models.common import PSpec

__all__ = ["mlp_specs", "mlp_apply"]


def mlp_specs(cfg: ArchConfig, kind: str, d_ff: int | None = None
              ) -> dict[str, PSpec]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if kind in ("swiglu", "geglu"):
        return {
            "wi": PSpec((d, f), ("embed", "mlp")),
            "wg": PSpec((d, f), ("embed", "mlp")),
            "wo": PSpec((f, d), ("mlp", "embed")),
        }
    if kind == "gelu":
        return {
            "wi": PSpec((d, f), ("embed", "mlp")),
            "bi": PSpec((f,), ("mlp",), init="zeros"),
            "wo": PSpec((f, d), ("mlp", "embed")),
            "bo": PSpec((d,), (None,), init="zeros"),
        }
    raise ValueError(kind)


def mlp_apply(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
        return h @ params["wo"]
    if kind == "geglu":
        h = jax.nn.gelu(x @ params["wg"], approximate=True) * (
            x @ params["wi"])
        return h @ params["wo"]
    if kind == "gelu":
        h = jax.nn.gelu(x @ params["wi"] + params["bi"].astype(x.dtype),
                        approximate=True)
        return h @ params["wo"] + params["bo"].astype(x.dtype)
    raise ValueError(kind)
