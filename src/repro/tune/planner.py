"""Autotuning planner: persistent, measured per-layer execution plans.

The :class:`Planner` owns the mapping ``PlanKey → Plan``:

* **PlanKey** — the full layer geometry (op kind, batch, spatial sizes,
  kernel, strides, paddings, channels) plus dtype and JAX platform.  Two
  dispatches with the same key are the same workload, so one measured
  plan serves both.
* **Plan** — the winning backend name, its tuned Pallas block shapes
  (``None`` for pure-JAX backends), the measured median wall-clock, and
  a provenance tag (``"measured"`` vs ``"heuristic"``).
* **Persistence** — plans live in memory and, when the planner has a
  ``path``, in a JSON plan file written atomically after every newly
  measured plan.  A corrupt or stale file (unparseable, wrong format
  version, entries naming unknown backends) degrades to an empty cache
  plus the heuristic — tuning is an optimization, never a failure mode.
* **Counters** — ``lookups`` / ``hits`` / ``measurements`` make the
  contract testable: a second process starting from a warm plan file
  must answer every ``plan()`` call with **zero** measurements.

``Planner.lookup`` is what ``DataflowPolicy(backend="auto")`` calls at
dispatch time; it never measures (dispatch can be inside a ``jit``
trace).  Measurement happens in ``Planner.plan`` / ``Planner.tune`` —
driven by ``python -m repro.tune``, ``GanServer`` construction warmup,
or user code.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import math
import os
import threading
from typing import Iterable, Sequence

import jax

from repro.core.dataflow import (DataflowPolicy, Epilogue,
                                 available_backends, backend_supports)

__all__ = ["PlanKey", "Plan", "Planner", "plan_key_for_op",
           "PLAN_FORMAT_VERSION"]

log = logging.getLogger(__name__)

PLAN_FORMAT_VERSION = 1


# PlanKey fields added by the fused-epilogue refactor: pre-epilogue plan
# files simply omit them, and from_json fills the defaults (= an identity
# epilogue), so old BENCH_tune.json / plan JSONs keep loading.
_EPILOGUE_FIELDS = ("bias", "activation", "leaky_slope")


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """One tunable workload: (layer geometry, epilogue, dtype, platform).

    The epilogue is part of the key because it is part of the op the
    plan will execute: a fused bias+activation changes the kernel's
    flush step (and the pure-JAX backends' fusion opportunities), so
    ``backend="auto"`` must tune the op that actually runs."""

    kind: str                       # "tconv" | "conv"
    batch: int
    in_spatial: tuple[int, ...]
    kernel: tuple[int, ...]
    strides: tuple[int, ...]
    paddings: tuple[int, ...]
    cin: int
    cout: int
    dtype: str = "float32"
    platform: str = "cpu"
    # -- fused epilogue (defaults = identity, matching pre-epilogue keys)
    bias: bool = False
    activation: str = "none"
    leaky_slope: float = 0.2

    @property
    def nd(self) -> int:
        return len(self.in_spatial)

    @property
    def epilogue(self) -> Epilogue:
        return Epilogue(bias=self.bias, activation=self.activation,
                        leaky_slope=self.leaky_slope)

    def describe(self) -> str:
        sp = "x".join(map(str, self.in_spatial))
        k = "x".join(map(str, self.kernel))
        s = "x".join(map(str, self.strides))
        ep = self.epilogue
        suffix = "" if ep.is_identity else f" ep[{ep.describe()}]"
        return (f"{self.kind} b{self.batch} {sp} k{k} s{s} "
                f"{self.cin}->{self.cout}{suffix} "
                f"{self.dtype}@{self.platform}")

    def to_json(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    @classmethod
    def from_json(cls, d: dict) -> "PlanKey":
        names = {f.name for f in dataclasses.fields(cls)}
        required = names - set(_EPILOGUE_FIELDS)
        if not (required <= set(d) <= names):
            raise ValueError(f"bad plan key fields: {sorted(d)}")
        d = dict(d)
        for f in ("in_spatial", "kernel", "strides", "paddings"):
            d[f] = tuple(int(v) for v in d[f])
        for f in ("batch", "cin", "cout"):
            d[f] = int(d[f])
        if "bias" in d:
            d["bias"] = bool(d["bias"])
        if "leaky_slope" in d:
            d["leaky_slope"] = float(d["leaky_slope"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class Plan:
    """The chosen execution path for one :class:`PlanKey`."""

    backend: str
    blocks: tuple[int, ...] | None = None   # Pallas tile shapes: a
    # (qy, cin, cout) triple for 2-D layers, (qz, qy, cin, cout) for 3-D
    measured_us: float | None = None            # winning median wall-clock
    source: str = "measured"                    # "measured" | "heuristic"

    def to_json(self) -> dict:
        return {"backend": self.backend,
                "blocks": list(self.blocks) if self.blocks else None,
                "measured_us": self.measured_us,
                "source": self.source}

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        backend = d["backend"]
        if not isinstance(backend, str):
            raise ValueError(f"bad plan backend: {backend!r}")
        blocks = d.get("blocks")
        if blocks is not None:
            blocks = tuple(int(v) for v in blocks)
            if len(blocks) not in (3, 4):   # 2-D triple / 3-D quadruple
                raise ValueError(f"bad plan blocks: {blocks!r}")
        us = d.get("measured_us")
        return cls(backend=backend, blocks=blocks,
                   measured_us=None if us is None else float(us),
                   source=str(d.get("source", "measured")))


def plan_key_for_op(kind: str, x, w, strides: Sequence[int],
                    paddings: Sequence[int],
                    epilogue: Epilogue | None = None) -> PlanKey:
    """Build the plan key for one unified-op dispatch (works on tracers:
    only shapes/dtypes are read).  ``epilogue`` folds the fused
    bias/activation spec into the key (None = identity)."""
    nd = x.ndim - 2
    ep = epilogue if epilogue is not None else Epilogue()
    return PlanKey(
        kind=kind,
        batch=int(x.shape[0]),
        in_spatial=tuple(int(d) for d in x.shape[1:1 + nd]),
        kernel=tuple(int(d) for d in w.shape[:nd]),
        strides=tuple(int(s) for s in strides),
        paddings=tuple(int(p) for p in paddings),
        cin=int(w.shape[-2]),
        cout=int(w.shape[-1]),
        dtype=str(jax.numpy.dtype(x.dtype)),
        platform=jax.default_backend(),
        **ep.key_fields(),
    )


class Planner:
    """In-memory + JSON-persisted plan cache with measured tuning.

    ``path=None`` keeps plans in memory only.  ``backends`` restricts the
    candidate pool (default: the platform's fast backends — see
    ``repro.tune.candidates``); ``warmup``/``repeats`` configure the
    measurement harness.
    """

    def __init__(self, path: str | os.PathLike | None = None, *,
                 backends: Sequence[str] | None = None,
                 warmup: int = 1, repeats: int = 5,
                 margin: float = 0.1):
        self.path = os.fspath(path) if path is not None else None
        self.backends = tuple(backends) if backends is not None else None
        self.warmup = int(warmup)
        self.repeats = int(repeats)
        # a candidate must beat the heuristic by this fraction to win the
        # plan: measured deltas inside the margin are noise, and flipping
        # backends on noise makes "tuned" randomly slower than "default"
        self.margin = float(margin)
        self.measurements = 0       # candidate configs actually timed
        self.lookups = 0
        self.hits = 0
        self.load_error: str | None = None
        self.stale_dropped = 0
        self._plans: dict[PlanKey, Plan] = {}
        self._lock = threading.RLock()
        if self.path is not None:
            self._load()

    # -- persistence --------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        try:
            with open(self.path) as f:
                doc = json.load(f)
            if not isinstance(doc, dict) or \
                    doc.get("version") != PLAN_FORMAT_VERSION:
                raise ValueError(
                    f"unsupported plan file version "
                    f"{doc.get('version') if isinstance(doc, dict) else doc!r}"
                    f" (want {PLAN_FORMAT_VERSION})")
            entries = doc.get("plans")
            if not isinstance(entries, list):
                raise ValueError("plan file has no 'plans' list")
        except Exception as e:  # corrupt file → heuristics, not a crash
            self.load_error = f"{type(e).__name__}: {e}"
            log.warning("ignoring corrupt plan file %s (%s); falling back "
                        "to heuristics", self.path, self.load_error)
            return
        for entry in entries:
            try:
                key = PlanKey.from_json(entry["key"])
                plan = Plan.from_json(entry["plan"])
                if plan.backend not in available_backends():
                    raise ValueError(f"unknown backend {plan.backend!r}")
                if not backend_supports(plan.backend, key.nd):
                    raise ValueError(f"backend {plan.backend!r} does not "
                                     f"support {key.nd}-D")
            except Exception as e:  # stale entry → drop just this one
                self.stale_dropped += 1
                log.warning("dropping stale plan entry (%s): %r", e, entry)
                continue
            self._plans[key] = plan

    def save(self) -> None:
        """Atomically write the plan file (no-op without a path)."""
        if self.path is None:
            return
        with self._lock:
            doc = {"version": PLAN_FORMAT_VERSION,
                   "plans": [{"key": k.to_json(), "plan": p.to_json()}
                             for k, p in self._plans.items()]}
        tmp = f"{self.path}.tmp.{os.getpid()}"
        os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                    exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._plans)

    def lookup(self, key: PlanKey) -> Plan | None:
        """Dispatch-time consult: cached plan or None.  Never measures."""
        with self._lock:
            self.lookups += 1
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
            return plan

    def put(self, key: PlanKey, plan: Plan) -> None:
        """Install a plan directly (hand-written or externally measured)
        and persist it."""
        with self._lock:
            self._plans[key] = plan
        self.save()

    def heuristic_plan(self, key: PlanKey) -> Plan:
        """What the static heuristic would run (not cached — a later
        ``plan()`` call should still be able to measure)."""
        return Plan(backend=DataflowPolicy().resolve(key.nd), blocks=None,
                    measured_us=None, source="heuristic")

    def plan(self, key: PlanKey, *, measure: bool = True) -> Plan:
        """The plan for ``key``: cached if known, freshly tuned when
        ``measure`` (the default), else the heuristic."""
        with self._lock:
            cached = self._plans.get(key)
            if cached is not None:
                return cached
        if not measure:
            return self.heuristic_plan(key)
        return self.tune(key)

    # -- tuning -------------------------------------------------------------
    def measure_candidates(self, key: PlanKey,
                           backends: Sequence[str] | None = None
                           ) -> dict:
        """Measure every valid candidate for ``key``; returns
        ``{Candidate: median_seconds}`` (failed candidates → inf).

        Runs are interleaved across candidates so they share noise
        windows — the ranking is what matters, not absolute numbers."""
        from repro.tune.candidates import enumerate_candidates
        from repro.tune.measure import measure_candidates_interleaved
        cands = enumerate_candidates(
            key, backends=backends if backends is not None
            else self.backends)
        timings = measure_candidates_interleaved(
            key, cands, warmup=self.warmup, repeats=self.repeats)
        with self._lock:
            self.measurements += sum(
                1 for t in timings.values() if math.isfinite(t))
        for cand, t in timings.items():
            if not math.isfinite(t):
                log.warning("candidate %r failed on %s", cand,
                            key.describe())
        return timings

    def tune(self, key: PlanKey,
             backends: Sequence[str] | None = None) -> Plan:
        """Measure the candidate set and cache + persist the winner.

        The heuristic configuration only loses when a candidate beats it
        by more than ``margin`` — within-noise deltas keep the default."""
        timings = self.measure_candidates(key, backends=backends)
        best = min(timings, key=timings.get, default=None)
        if best is None or not math.isfinite(timings[best]):
            plan = self.heuristic_plan(key)   # nothing measurable
        else:
            heur_backend = self.heuristic_plan(key).backend
            # first candidate of the heuristic backend == default blocks
            heur_cand = next((c for c in timings
                              if c.backend == heur_backend), None)
            if heur_cand is not None and \
                    math.isfinite(timings[heur_cand]) and \
                    timings[best] >= (1 - self.margin) * \
                    timings[heur_cand]:
                best = heur_cand
            plan = Plan(backend=best.backend, blocks=best.blocks,
                        measured_us=timings[best] * 1e6, source="measured")
        with self._lock:
            self._plans[key] = plan
        self.save()
        return plan

    def warm(self, keys: Iterable[PlanKey], *,
             measure: bool = True) -> dict[PlanKey, Plan]:
        """Resolve plans for many keys up front (e.g. every layer of a
        model before the first jit trace)."""
        return {k: self.plan(k, measure=measure) for k in keys}

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {"plans": len(self._plans), "lookups": self.lookups,
                    "hits": self.hits, "measurements": self.measurements,
                    "stale_dropped": self.stale_dropped}

    def __repr__(self) -> str:
        src = f"path={self.path!r}" if self.path else "in-memory"
        return (f"Planner({src}, plans={len(self._plans)}, "
                f"measurements={self.measurements})")
