"""``python -m repro.tune`` — tune the Table-I GAN model zoo and write
``BENCH_tune.json`` (tuned vs heuristic wall-clock per model).

Typical use::

    PYTHONPATH=src python -m repro.tune                 # whole zoo
    PYTHONPATH=src python -m repro.tune --models dcgan \
        --plans /tmp/plans.json --repeats 5

The plan file (``--plans``) is the persistent cache: re-running with a
warm file performs zero measurements and only re-times the end-to-end
generators.  Point ``REPRO_TUNE_PLANS`` at the same file so training and
serving processes pick the plans up with ``backend="auto"``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.configs.gans import GAN_MODELS
from repro.core.dataflow import available_backends
from repro.tune.planner import Planner
from repro.tune.zoo import tune_model_zoo


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Measure per-layer backend & Pallas block-shape "
                    "plans for the Table-I GAN model zoo.")
    ap.add_argument("--models", nargs="+", default=sorted(GAN_MODELS),
                    choices=sorted(GAN_MODELS),
                    help="models to tune (default: the whole zoo)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--channel-scale", type=float, default=0.25,
                    help="shrink channels for CPU-sized measurement")
    ap.add_argument("--backends", nargs="+", default=None,
                    help="restrict the candidate backend pool "
                         f"(registered: {', '.join(available_backends())};"
                         " default: the platform's fast paths)")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed runs per candidate (median reported)")
    ap.add_argument("--plans", default=None, metavar="PATH",
                    help="persistent JSON plan file (default: in-memory)")
    ap.add_argument("--out", default="BENCH_tune.json", metavar="PATH")
    ap.add_argument("--no-e2e", action="store_true",
                    help="skip the end-to-end generator timings")
    args = ap.parse_args(argv)

    if args.backends:
        unknown = set(args.backends) - set(available_backends())
        if unknown:
            ap.error(f"unknown backends {sorted(unknown)}; "
                     f"registered: {available_backends()}")

    planner = Planner(args.plans, backends=args.backends,
                      warmup=args.warmup, repeats=args.repeats)
    if planner.load_error:
        print(f"warning: plan file ignored ({planner.load_error})")

    print(f"== repro.tune: {len(args.models)} models, batch={args.batch}, "
          f"channels×{args.channel_scale} ==")
    bench = tune_model_zoo(args.models, planner, batch=args.batch,
                           channel_scale=args.channel_scale,
                           warmup=args.warmup, repeats=args.repeats,
                           end_to_end=not args.no_e2e)

    stats = planner.stats()
    bench["_meta"] = {
        "batch": args.batch,
        "channel_scale": args.channel_scale,
        "repeats": args.repeats,
        "planner": stats,
        "plan_file": args.plans,
    }
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(bench, indent=2, sort_keys=True) + "\n")
    print(f"planner: {stats['plans']} plans, "
          f"{stats['measurements']} measurements this run")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
