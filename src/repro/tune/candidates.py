"""Candidate enumeration: (backend × Pallas block shape) configurations
valid for one layer geometry.

The fused epilogue rides in the :class:`~repro.tune.planner.PlanKey`
(``bias``/``activation``/``leaky_slope``), not in the candidates: every
candidate of an epilogue-carrying key is measured running the fused op
(see ``measure._candidate_fn``), and the VMEM budget accounts for the
kernel's extra bias block.

The enumerator is pure geometry — it reuses the cached μop compilation
(`core.dataflow.compile_uops` / `compile_conv_uops`) to learn the
phase-plane extents and padding plan, then emits:

* one candidate per eligible **pure-JAX backend** (``polyphase``,
  ``zero-insert``) — no block shapes to choose;
* for each eligible **Pallas backend**, the default block shapes first
  (so the heuristic is always in the measured pool) followed by the
  valid divisor alternatives of (block_qy, block_cin, block_cout),
  filtered by a VMEM footprint budget.

Eligibility: a backend must be registered, support the spatial rank, and
be a *fast path* on the current platform — ``pallas-tpu`` only runs on
TPU hosts, and interpret-mode Pallas is a correctness tool (Python-speed,
never a sensible plan), so neither appears in a CPU candidate pool unless
explicitly requested via ``backends=``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import jax
import numpy as np

from repro.core.dataflow import (backend_supports, compile_conv_uops,
                                 compile_uops)
from repro.kernels.ops import default_blocks
from repro.tune.planner import PlanKey

__all__ = ["Candidate", "enumerate_candidates", "default_backend_pool",
           "VMEM_BUDGET_BYTES"]

# Per-step VMEM footprint ceiling for a candidate (a TPU core has ~16 MiB;
# leave headroom for double buffering).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

# Most candidates per Pallas backend (default blocks always included).
MAX_BLOCK_CANDIDATES = 12


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One runnable configuration: backend + optional Pallas blocks
    (a (qy, cin, cout) triple for 2-D layers, (qz, qy, cin, cout) for
    volumetric ones)."""

    backend: str
    blocks: tuple[int, ...] | None = None

    def describe(self) -> str:
        if self.blocks is None:
            return self.backend
        return f"{self.backend}[{'x'.join(map(str, self.blocks))}]"


def default_backend_pool(platform: str | None = None) -> tuple[str, ...]:
    """The fast-path backends worth measuring on ``platform``."""
    platform = platform or jax.default_backend()
    if platform == "tpu":
        return ("pallas-tpu", "polyphase", "zero-insert")
    return ("polyphase", "zero-insert")


def _divisor_options(extent: int, preferred: Sequence[int]) -> list[int]:
    """Divisors of ``extent`` drawn from ``preferred`` (order kept,
    deduplicated, always non-empty because ``extent`` divides itself)."""
    opts = []
    for v in list(preferred) + [extent]:
        if v > 0 and extent % v == 0 and v not in opts:
            opts.append(v)
    return opts


def _pallas_geometry(key: PlanKey
                     ) -> tuple[tuple[int, ...], int, tuple[int, ...]]:
    """(q_sizes, taps, padded_spatial) of the kernel invocation for
    ``key`` — rank-generic: 2-D rows or 3-D (planes, rows)."""
    if key.kind == "tconv":
        u = compile_uops(key.in_spatial, key.kernel, key.strides,
                         key.paddings)
        q_sizes = u.q_sizes
        taps = u.tap_dy.shape[1]
    else:
        u = compile_conv_uops(key.in_spatial, key.kernel, key.strides,
                              key.paddings)
        q_sizes = u.out_sizes
        taps = int(np.prod(key.kernel))
    padded = tuple(i + lo + hi
                   for i, (lo, hi) in zip(key.in_spatial, u.pad))
    return q_sizes, taps, padded


def _vmem_bytes(key: PlanKey, q_sizes: tuple[int, ...], taps: int,
                padded: tuple[int, ...], blocks: tuple[int, ...]) -> int:
    # Precision audit (repro.quant): x/w/out VMEM blocks scale with the
    # *storage* itemsize carried in the plan key's dtype (2 B at
    # bf16/f16), while the accumulator scratch and the fused-epilogue
    # bias block are hardwired ``* 4`` — deliberately: the kernel
    # accumulates in f32 at every storage precision, so those two terms
    # never shrink with the storage dtype.
    lead, (bci, bco) = blocks[:-2], blocks[-2:]
    itemsize = jax.numpy.dtype(key.dtype).itemsize
    rows = int(np.prod(lead)) * q_sizes[-1]
    x_blk = int(np.prod(padded)) * bci * itemsize
    w_blk = taps * bci * bco * itemsize
    out_blk = rows * bco * itemsize
    acc = rows * bco * 4  # f32 accumulator scratch
    bias = bco * 4 if key.bias else 0  # fused-epilogue (1, bco) f32 block
    return x_blk + w_blk + out_blk + acc + bias


def _pallas_candidates(key: PlanKey, backend: str) -> list[Candidate]:
    q_sizes, taps, padded = _pallas_geometry(key)
    dflt = default_blocks(q_sizes[:-1], key.cin, key.cout)
    # one tiled option list per leading phase-plane extent: qy for 2-D,
    # (qz, qy) for the volumetric sweep
    lead_opts = [_divisor_options(extent, [d, 16, 8, 4])
                 for extent, d in zip(q_sizes[:-1], dflt[:-2])]
    bci_opts = _divisor_options(key.cin, [dflt[-2], 256, 128, 64])
    bco_opts = _divisor_options(key.cout, [dflt[-1], 256, 128, 64])
    out = [Candidate(backend, dflt)]
    for blocks in itertools.product(*lead_opts, bci_opts, bco_opts):
        if blocks == dflt or \
                _vmem_bytes(key, q_sizes, taps, padded, blocks) > \
                VMEM_BUDGET_BYTES:
            continue
        out.append(Candidate(backend, blocks))
        if len(out) >= MAX_BLOCK_CANDIDATES:
            break
    # the default stays even when over budget elsewhere would drop it: it
    # is the comparison baseline the planner reports speedups against
    return out


def enumerate_candidates(key: PlanKey,
                         backends: Sequence[str] | None = None
                         ) -> list[Candidate]:
    """Every configuration worth measuring for ``key``, heuristic
    defaults first within each backend."""
    pool = tuple(backends) if backends is not None else \
        default_backend_pool(key.platform)
    out: list[Candidate] = []
    for backend in pool:
        if not backend_supports(backend, key.nd):
            continue
        if backend.startswith("pallas"):
            out.extend(_pallas_candidates(key, backend))
        else:
            out.append(Candidate(backend))
    return out
