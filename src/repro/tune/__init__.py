"""Autotuning planner subsystem: measured per-layer backend & Pallas
block-shape selection with persistent plans.

Quick map:

* :mod:`repro.tune.planner` — :class:`Planner` (in-memory + JSON plan
  file, measurement counters, corrupt/stale fallback), :class:`PlanKey`,
  :class:`Plan`.
* :mod:`repro.tune.candidates` — enumerate the (backend × block shape)
  configurations valid for a layer geometry.
* :mod:`repro.tune.measure` — warmup + median-of-k timing of one
  candidate on the unified op.
* :mod:`repro.tune.zoo` — tune the Table-I GAN model zoo; backs the
  ``python -m repro.tune`` CLI which writes ``BENCH_tune.json``.

The process-wide planner (:func:`get_planner`) is what
``DataflowPolicy(backend="auto")`` consults at dispatch time.  Its plan
file defaults to ``$REPRO_TUNE_PLANS`` (in-memory only when unset);
install a configured planner with :func:`set_planner`.
"""

from __future__ import annotations

import os

from repro import obs as _obs
from repro.tune.candidates import (Candidate, default_backend_pool,
                                   enumerate_candidates)
from repro.tune.measure import (measure_candidate, synthesize_inputs,
                                time_fn)
from repro.tune.planner import (PLAN_FORMAT_VERSION, Plan, PlanKey,
                                Planner, plan_key_for_op)
from repro.tune.zoo import layer_plan_keys, tune_model_zoo, warm_gan_plans

__all__ = [
    "Candidate", "Plan", "PlanKey", "Planner", "PLAN_FORMAT_VERSION",
    "default_backend_pool", "enumerate_candidates", "measure_candidate",
    "synthesize_inputs", "time_fn", "plan_key_for_op", "layer_plan_keys",
    "warm_gan_plans", "tune_model_zoo", "get_planner", "set_planner",
]

_PLANNER: Planner | None = None


def get_planner(create: bool = True) -> Planner | None:
    """The process-wide planner consulted by ``backend="auto"``.

    Created lazily on first use; persists to the path in the
    ``REPRO_TUNE_PLANS`` environment variable when set (in-memory
    otherwise).  ``create=False`` returns None instead of creating one —
    for observers (e.g. the train loop's stats logging) that must not
    allocate a planner as a side effect."""
    global _PLANNER
    if _PLANNER is None and create:
        _PLANNER = Planner(path=os.environ.get("REPRO_TUNE_PLANS"))
    return _PLANNER


def set_planner(planner: Planner | None) -> Planner | None:
    """Install (or clear, with None) the process-wide planner."""
    global _PLANNER
    _PLANNER = planner
    return planner


def _planner_stats():
    """Process-wide planner counters for ``obs.collect()``, or None when
    no planner exists (observing must not create one)."""
    planner = get_planner(create=False)
    return None if planner is None else planner.stats()


_obs.register_collector("tune.planner", _planner_stats)
