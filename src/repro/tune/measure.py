"""Measurement harness: timed runs of the unified op for one candidate.

Timing methodology: the candidate is jit-compiled once, warmed up
(compile + cache effects excluded), then run ``repeats`` times with a
``block_until_ready`` fence around each run; the **median** is the
reported cost (robust to scheduler noise — one slow outlier cannot
promote or demote a candidate).

Inputs are synthesized from the :class:`~repro.tune.planner.PlanKey`
geometry (timing depends on shapes/dtypes, not values), deterministically
seeded so re-measurement is reproducible.  The μop compilation stage is
shared with production dispatch through the ``core.dataflow`` LRU cache,
so tuning a geometry also pre-warms its schedule for later serving.
"""

from __future__ import annotations

import statistics
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core.dataflow import DataflowPolicy
from repro.core.dataflow import conv as df_conv
from repro.core.dataflow import tconv as df_tconv
from repro.tune.candidates import Candidate
from repro.tune.planner import PlanKey

__all__ = ["synthesize_inputs", "synthesize_bias", "measure_candidate",
           "measure_candidates_interleaved", "time_fn",
           "time_interleaved"]


def synthesize_inputs(key: PlanKey) -> tuple[jax.Array, jax.Array]:
    """Deterministic random (x, w) with the key's shapes and dtype."""
    rng = np.random.default_rng(zlib.crc32(key.describe().encode()))
    dtype = jnp.dtype(key.dtype)
    x = jnp.asarray(rng.normal(
        size=(key.batch, *key.in_spatial, key.cin)), dtype)
    w = jnp.asarray(rng.normal(
        size=(*key.kernel, key.cin, key.cout)), dtype)
    return x, w


def synthesize_bias(key: PlanKey) -> jax.Array | None:
    """Deterministic random bias for keys whose epilogue carries one
    (None otherwise) — timing must exercise the fused bias path."""
    if not key.bias:
        return None
    rng = np.random.default_rng(zlib.crc32(key.describe().encode()) + 1)
    return jnp.asarray(rng.normal(size=(key.cout,)), jnp.dtype(key.dtype))


def time_fn(fn, *args, warmup: int = 1, repeats: int = 5) -> float:
    """Median wall-clock seconds of ``fn(*args)`` over ``repeats`` timed
    runs after ``warmup`` untimed ones."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def time_interleaved(thunks, *, warmup: int = 1, repeats: int = 5,
                     reduce: str = "median") -> list[float]:
    """Seconds per thunk, with the timed runs interleaved round-robin
    (A,B,C,A,B,C,…) and the start position rotated per round.

    Interleaving makes competing configurations share every noise
    window, so their *ranking* is meaningful on a contended host where
    back-to-back timing is not; the rotation stops whoever runs first in
    a round from always paying the cold-cache/page-fault cost.

    ``reduce`` picks the per-thunk aggregate: ``"median"`` (default —
    representative cost, right for ranking candidates) or ``"min"``
    (the noise-floor estimate — scheduling noise is strictly additive,
    so the minimum approaches each thunk's intrinsic time; right when
    comparing two nearly identical thunks for a sub-percent delta)."""
    for th in thunks:
        for _ in range(warmup):
            jax.block_until_ready(th())
    if reduce not in ("median", "min"):
        raise ValueError(f"unknown reduce {reduce!r}")
    times: list[list[float]] = [[] for _ in thunks]
    for r in range(max(1, repeats)):
        for i in range(len(thunks)):
            j = (r + i) % len(thunks)
            t0 = time.perf_counter()
            jax.block_until_ready(thunks[j]())
            times[j].append(time.perf_counter() - t0)
    agg = min if reduce == "min" else statistics.median
    return [agg(t) for t in times]


def _candidate_fn(key: PlanKey, cand: Candidate):
    """Jit-compiled forward op for one candidate.

    Forward-only (``differentiable=False``): tuning targets the serving /
    inference hot path; training reuses the tuned forward and the
    heuristic backward (see ``core.dataflow``).  The key's epilogue is
    part of the measured op — a fused bias+activation plan must be won
    by timing the fused kernel, not the bare accumulator flush (the
    bias values are a jit constant: timing depends on shapes only)."""
    op = df_tconv if key.kind == "tconv" else df_conv
    policy = DataflowPolicy(backend=cand.backend, differentiable=False)
    epilogue = key.epilogue
    bias = synthesize_bias(key)

    @jax.jit
    def run(x, w):
        return op(x, w, key.strides, key.paddings, policy=policy,
                  blocks=cand.blocks, bias=bias, epilogue=epilogue)

    return run


def measure_candidate(key: PlanKey, cand: Candidate, *,
                      warmup: int = 1, repeats: int = 5) -> float:
    """Median seconds per call of ``cand`` on ``key``'s workload.
    Raises on candidates that fail to compile or run — the planner
    treats that as an infinite cost, not an error."""
    x, w = synthesize_inputs(key)
    with _obs.trace("tune.measure", kind=key.kind,
                    backend=cand.backend, candidates=1):
        t = time_fn(_candidate_fn(key, cand), x, w, warmup=warmup,
                    repeats=repeats)
    _obs.counter("tune.measurements").inc()
    _obs.event("tune.candidate", backend=cand.backend,
               blocks=cand.blocks, us=t * 1e6)
    return t


def measure_candidates_interleaved(key: PlanKey,
                                   cands: list[Candidate], *,
                                   warmup: int = 1, repeats: int = 5
                                   ) -> dict[Candidate, float]:
    """Median seconds per call for each candidate via
    :func:`time_interleaved` — back-to-back per-candidate timing lets one
    slow scheduler window hand the plan to the wrong backend.

    Candidates that fail to compile/warm up get ``inf`` (and are skipped
    in the timed rounds)."""
    x, w = synthesize_inputs(key)
    with _obs.trace("tune.measure", kind=key.kind,
                    candidates=len(cands)) as sp:
        good: list[Candidate] = []
        thunks = []
        for cand in cands:
            try:
                fn = _candidate_fn(key, cand)
                for _ in range(max(1, warmup)):  # warm here: failure
                    jax.block_until_ready(fn(x, w))  # drops only this one
            except Exception:
                continue
            good.append(cand)
            thunks.append(lambda fn=fn: fn(x, w))
        out = {c: float("inf") for c in cands}
        timings = time_interleaved(thunks, warmup=0, repeats=repeats)
        out.update(zip(good, timings))
        sp.set(measured=len(good), skipped=len(cands) - len(good))
    _obs.counter("tune.measurements").inc(len(good))
    _obs.counter("tune.measurements_skipped").inc(len(cands) - len(good))
    for cand, t in zip(good, timings):
        _obs.event("tune.candidate", backend=cand.backend,
                   blocks=cand.blocks, us=t * 1e6)
    return out
