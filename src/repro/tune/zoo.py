"""Tuning entry points for the Table-I GAN model zoo.

The per-model layer walk lives in :class:`repro.program.ProgramSpec` —
the zoo derives every plan key from a built spec
(``spec.plan_keys()``), so the tuner keys exactly the fused ops the
programs execute, with no duplicated layer-group/epilogue threading
here.  ``layer_plan_keys`` turns a raw layer topology into plan keys
(the spec-free form); ``warm_gan_plans`` resolves (measuring on miss) a
plan for every layer of a config; ``tune_model_zoo`` drives the whole
zoo and produces the ``BENCH_tune.json`` payload (tuned vs heuristic
wall-clock per model).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.dataflow import DataflowPolicy
from repro.tune.measure import time_interleaved
from repro.tune.planner import Plan, PlanKey, Planner

__all__ = ["layer_plan_keys", "warm_gan_plans", "tune_model_zoo"]


def layer_plan_keys(layers, batch: int, dtype: str = "float32",
                    platform: str | None = None, epilogues=None
                    ) -> list[tuple[str, PlanKey]]:
    """(layer name, PlanKey) per ConvLayer-like entry in ``layers``.

    ``epilogues`` (an optional per-layer :class:`Epilogue` sequence)
    folds the fused bias/activation specs into the keys — warmed plans
    are only found at dispatch when they key the op the model actually
    runs, which since the fused-epilogue refactor includes the
    epilogue."""
    platform = platform or jax.default_backend()
    if epilogues is None:
        epilogues = [None] * len(layers)
    out = []
    for l, ep in zip(layers, epilogues):
        out.append((l.name, PlanKey(
            kind="tconv" if l.transposed else "conv",
            batch=int(batch),
            in_spatial=tuple(l.in_spatial),
            kernel=tuple(l.kernel),
            strides=tuple(l.strides),
            paddings=tuple(l.paddings),
            cin=int(l.cin), cout=int(l.cout),
            dtype=dtype, platform=platform,
            **({} if ep is None else ep.key_fields()))))
    return out


def _zoo_keys(cfg, batch: int, *, generator_only: bool = False,
              dtype: str = "float32") -> list[tuple[str, PlanKey]]:
    """("g/<name>" | "d/<name>", PlanKey) per layer of a ``GanConfig``
    — derived from :class:`~repro.program.ProgramSpec`, the single
    owner of the layer/epilogue walk, so tuner keys and program
    dispatches agree by construction."""
    from repro.program import ProgramSpec
    roles = [("g", "generator")]
    if not generator_only:
        roles.append(("d", "discriminator"))
    out = []
    for prefix, role in roles:
        # the heuristic policy keeps spec construction planner-free —
        # only the geometry/epilogue records matter for the keys
        spec = ProgramSpec.build(cfg, batch, role,
                                 policy=DataflowPolicy(), dtype=dtype)
        out.extend((f"{prefix}/{name}", key)
                   for name, key in spec.plan_keys())
    return out


def warm_gan_plans(cfg, batch: int, planner: Planner | None = None, *,
                   generator_only: bool = False, measure: bool = True,
                   dtype: str = "float32") -> dict[str, Plan]:
    """Resolve a plan for every layer of ``cfg`` (a ``GanConfig``),
    keyed on the fused per-layer epilogues the model dispatches.

    Returns ``{"g/<name>" | "d/<name>": Plan}``.  With a warm plan cache
    (or persisted plan file) this performs zero measurements."""
    if planner is None:
        from repro.tune import get_planner
        planner = get_planner()
    return {name: planner.plan(key, measure=measure)
            for name, key in _zoo_keys(cfg, batch,
                                       generator_only=generator_only,
                                       dtype=dtype)}


def _time_generator_pair(cfg, params, z, policies, *, warmup: int,
                         repeats: int) -> list[float]:
    """Median seconds per call for several policies on the same compiled
    generator, timed with the shared interleaved harness so the
    tuned-vs-heuristic ratio is meaningful on a noisy host."""
    from repro.models.gan import generator_apply

    thunks = []
    for policy in policies:
        @jax.jit
        def run(params, z, policy=policy):
            return generator_apply(params, z, cfg, policy=policy)
        thunks.append(lambda run=run: run(params, z))
    return time_interleaved(thunks, warmup=max(1, warmup),
                            repeats=repeats)


def tune_model_zoo(models: Sequence[str], planner: Planner, *,
                   batch: int = 2, channel_scale: float = 0.25,
                   warmup: int = 1, repeats: int = 3,
                   end_to_end: bool = True, log=print) -> dict:
    """Tune every layer of every model in ``models``; return the
    ``BENCH_tune.json`` payload.

    Per model: every layer geometry is tuned through the planner (shared
    geometries across models hit the plan cache), then — when
    ``end_to_end`` — the full generator forward is timed once with the
    heuristic policy and once with ``backend="auto"`` consulting the
    freshly tuned plans."""
    from repro.models.gan import GanConfig, init_gan

    out: dict[str, dict] = {}
    for name in models:
        cfg = GanConfig(name=name, channel_scale=channel_scale)
        meas0 = planner.measurements
        plans = warm_gan_plans(cfg, batch, planner)
        keys = dict(_zoo_keys(cfg, batch))
        layer_rows = {}
        tuned_us = heur_us = 0.0
        complete = True
        for lname, plan in plans.items():
            heur = planner.heuristic_plan(keys[lname])
            row = {"backend": plan.backend,
                   "blocks": list(plan.blocks) if plan.blocks else None,
                   "source": plan.source,
                   "tuned_us": plan.measured_us,
                   "heuristic_backend": heur.backend}
            layer_rows[lname] = row
            if plan.measured_us is None:
                complete = False
            else:
                tuned_us += plan.measured_us
        row = {"layers": layer_rows,
               "measurements": planner.measurements - meas0,
               "layer_tuned_us_sum": tuned_us if complete else None}
        if end_to_end:
            g_params, _ = init_gan(cfg, jax.random.PRNGKey(0))
            z = jnp.zeros((batch, cfg.z_dim), jnp.float32)
            # "auto" dispatch consults the *process-wide* planner; point
            # it at the one we just tuned for the timed run
            from repro.tune import get_planner, set_planner
            prev = get_planner(create=False)
            set_planner(planner)
            try:
                heur_s, tuned_s = _time_generator_pair(
                    cfg, g_params, z,
                    [DataflowPolicy(), DataflowPolicy(backend="auto")],
                    warmup=warmup, repeats=max(repeats, 5))
            finally:
                set_planner(prev)
            heur_us, tuned_e2e_us = heur_s * 1e6, tuned_s * 1e6
            row["generator_heuristic_us"] = heur_us
            row["generator_tuned_us"] = tuned_e2e_us
            row["generator_speedup"] = heur_us / tuned_e2e_us \
                if tuned_e2e_us else None
            log(f"  {name:9s} generator: heuristic={heur_us:9.0f}us  "
                f"tuned={tuned_e2e_us:9.0f}us  "
                f"speedup={row['generator_speedup']:.2f}x  "
                f"({row['measurements']} measurements)")
        else:
            log(f"  {name:9s} tuned {len(layer_rows)} layers "
                f"({row['measurements']} measurements)")
        out[name] = row
    return out
