"""Checked-in output-tolerance gates for low-precision execution.

Precision loss is a measured, versioned contract, not vibes: for every
Table-I model this module pins how far a low-precision run may drift
from the float32 reference, and ``tests/test_quant.py`` enforces the
numbers.  Tightening a kernel? the gates document the win.  A change
that blows a gate is a numerics regression and fails CI.

Two granularities:

* :func:`model_tolerance` — full-generator gates per (model, dtype).
  Generator outputs are tanh-bounded in ``[-1, 1]``, so the output
  gate is an absolute tolerance; the gradient gate is a relative L2
  error over the whole parameter-gradient tree (gradients are not
  bounded, so an elementwise atol would be meaningless).
* :func:`op_tolerance` — single-op forward/grad gates per dtype, used
  by the backend × kind × rank × stride parity sweep on unit-normal
  inputs.

``"int8"`` gates the int8-weight deployment path (per-channel
symmetric weights dequantized into the model's storage dtype) for the
*forward* only — quantized programs are a serving artifact, there is
no int8 training path to gate.
"""

from __future__ import annotations

__all__ = ["MODEL_TOLERANCES", "OP_TOLERANCES", "model_tolerance",
           "op_tolerance"]

# Per-model gates, calibrated on the CPU CI configuration
# (channel_scale=0.0625, batch 2, seed 0, polyphase backend) with
# 5-10x headroom over the observed drift so backend choice
# (zero-insert, interpret-mode kernel) and runner-to-runner noise
# never flip them while a real numerics regression (an order of
# magnitude) still does.
#   output_atol — max |low-precision - f32| over the generator output
#                 (tanh-bounded, so absolute)
#   grad_rel    — relative L2 error of the full parameter-grad tree
#                 (None = no training gate at this precision)
MODEL_TOLERANCES: dict[str, dict[str, dict]] = {
    "3dgan": {   # observed: bf16 1.4e-5/5.4e-3, f16 1.6e-6/6.9e-4
        "bfloat16": {"output_atol": 1e-4, "grad_rel": 0.02},
        "float16":  {"output_atol": 2e-5, "grad_rel": 3e-3},
        "int8":     {"output_atol": 2e-4, "grad_rel": None},
    },
    "artgan": {  # observed: bf16 3.9e-5/3.6e-3, f16 3.8e-6/3.0e-4
        "bfloat16": {"output_atol": 2e-4, "grad_rel": 0.015},
        "float16":  {"output_atol": 2e-5, "grad_rel": 2e-3},
        "int8":     {"output_atol": 5e-4, "grad_rel": None},
    },
    "dcgan": {   # observed: bf16 3.5e-5/1.6e-3, f16 6.1e-6/5.6e-4
        "bfloat16": {"output_atol": 2e-4, "grad_rel": 0.01},
        "float16":  {"output_atol": 3e-5, "grad_rel": 3e-3},
        "int8":     {"output_atol": 5e-4, "grad_rel": None},
    },
    "discogan": {  # observed: bf16 1.2e-6/1.7e-3, f16 2e-7/1.4e-3
        "bfloat16": {"output_atol": 1e-5, "grad_rel": 0.01},
        "float16":  {"output_atol": 2e-6, "grad_rel": 6e-3},
        "int8":     {"output_atol": 2e-5, "grad_rel": None},
    },
    "gpgan": {   # observed: bf16 4.6e-5/1.6e-3, f16 5.9e-6/3.2e-4
        "bfloat16": {"output_atol": 2e-4, "grad_rel": 0.01},
        "float16":  {"output_atol": 3e-5, "grad_rel": 2e-3},
        "int8":     {"output_atol": 5e-4, "grad_rel": None},
    },
    "magan": {   # observed: bf16 1.0e-4/6.5e-3, f16 7.9e-6/2.0e-4
        "bfloat16": {"output_atol": 5e-4, "grad_rel": 0.02},
        "float16":  {"output_atol": 4e-5, "grad_rel": 2e-3},
        "int8":     {"output_atol": 8e-4, "grad_rel": None},
    },
}

# Single-op parity gates on unit-normal inputs, calibrated over the
# runnable-backend × kind × rank × stride sweep of tests/test_quant.py
# with ~3-4x headroom (observed worst cases in the comments).
#   "fwd"      — (rtol, atol) for np.testing.assert_allclose against
#                the f32 forward.
#   "grad_rel" — relative L2 ceiling per input/weight cotangent.  The
#                backward re-rounds through the low-precision operands
#                in *two* more contractions (dx conv, dw einsum), so an
#                elementwise gate would be noise-bound where the
#                cotangent crosses zero; the L2 form measures the
#                drift that matters.
OP_TOLERANCES: dict[str, dict[str, object]] = {
    # observed: fwd 2.8e-2 (rel+abs combined), grad 4.6e-3
    "bfloat16": {"fwd": (0.08, 0.08), "grad_rel": 0.02},
    # observed: fwd 2.1e-3, grad 6.6e-4
    "float16":  {"fwd": (8e-3, 8e-3), "grad_rel": 3e-3},
}


def model_tolerance(model: str, dtype: str) -> dict:
    """The checked-in gate for (Table-I model, precision); raising
    ``KeyError`` for unknown pairs is the point — a new model or
    precision must check its numbers in here before it ships."""
    return MODEL_TOLERANCES[model][dtype]


def op_tolerance(dtype: str, what: str = "fwd"):
    """The single-op parity gate: ``what="fwd"`` returns the
    ``(rtol, atol)`` allclose pair, ``what="grad_rel"`` the relative-L2
    ceiling for the cotangents."""
    return OP_TOLERANCES[dtype][what]
