"""Per-channel symmetric int8 weight quantization as an export transform.

The quantization scheme is the standard deployment form for
bandwidth-bound GAN generators: every parameter tensor of rank ≥ 2
(conv kernels, the z-projection matrix) is quantized **per output
channel** (its last axis) to symmetric int8 — ``scale = absmax / 127``
per channel, values rounded to ``[-127, 127]`` — while rank-1 tensors
(biases) stay float32, since they feed the f32 accumulator path anyway.

This is a *program-export* transform, not a runtime one:
:func:`quantize_program` embeds the quantized tree into a
:class:`~repro.program.ProgramSpec` (serialized in the version-3
program JSON as base64 arrays), and :class:`repro.program.Program`
dequantizes it into the spec's storage dtype once at load.
Dequantization is deterministic — two loads of the same file produce
bit-identical parameters, so a quantized program serves bit-stably.
"""

from __future__ import annotations

import base64
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.quant.precision import canonical_dtype, storage_dtype

__all__ = ["QUANT_SCHEME", "quantize_weight", "dequantize_weight",
           "quantize_params", "dequantize_params", "quantize_program",
           "validate_quantized"]

# Scheme tag written into the program JSON; a future asymmetric /
# per-group scheme bumps this string, and loaders reject unknown tags.
QUANT_SCHEME = "int8-symmetric-perchannel"


# -- array <-> JSON ----------------------------------------------------------

def _encode(arr: np.ndarray) -> dict:
    arr = np.ascontiguousarray(arr)
    return {"shape": list(arr.shape), "dtype": str(arr.dtype),
            "data": base64.b64encode(arr.tobytes()).decode("ascii")}


def _decode(doc) -> np.ndarray:
    if not isinstance(doc, dict) or \
            not {"shape", "dtype", "data"} <= set(doc):
        raise ValueError(f"bad quantized-array record: "
                         f"{sorted(doc) if isinstance(doc, dict) else doc!r}")
    dtype = np.dtype(str(doc["dtype"]))
    shape = tuple(int(v) for v in doc["shape"])
    raw = base64.b64decode(str(doc["data"]).encode("ascii"))
    n = int(np.prod(shape)) if shape else 1
    if len(raw) != n * dtype.itemsize:
        raise ValueError(f"quantized array payload is {len(raw)} bytes, "
                         f"want {n * dtype.itemsize} for shape {shape} "
                         f"{dtype}")
    return np.frombuffer(raw, dtype=dtype).reshape(shape)


# -- per-tensor quantize / dequantize ----------------------------------------

def quantize_weight(w) -> tuple[np.ndarray, np.ndarray]:
    """f32 tensor → (int8 values, per-output-channel f32 scales).

    Symmetric per-channel over the **last** axis (Cout for the conv
    kernels, the projection width for ``proj_w``): ``scale =
    absmax / 127``; an all-zero channel gets scale 1 so dequantization
    stays exact (0 · 1 = 0)."""
    w = np.asarray(w, dtype=np.float32)
    if w.ndim < 2:
        raise ValueError(f"per-channel quantization needs rank >= 2, "
                         f"got shape {w.shape}")
    absmax = np.max(np.abs(w), axis=tuple(range(w.ndim - 1)))
    scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(w / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_weight(q, scale, dtype="float32") -> jnp.ndarray:
    """(int8 values, f32 scales) → dense tensor in the storage dtype.
    The multiply runs in f32 and casts once, mirroring the f32-
    accumulate / cast-at-flush convention everywhere else."""
    w = jnp.asarray(np.asarray(q), jnp.float32) * \
        jnp.asarray(np.asarray(scale), jnp.float32)
    return w.astype(storage_dtype(dtype))


# -- whole-tree quantize / dequantize ----------------------------------------

def quantize_params(params: dict) -> dict:
    """Flat ``{name: array}`` param dict → JSON-able quantized blob.

    Rank ≥ 2 tensors go int8 per-channel; rank-0/1 tensors (biases)
    are kept as raw f32 — they are a rounding error of the payload and
    feed the f32 accumulator path directly."""
    out = {}
    for name in sorted(params):
        arr = np.asarray(params[name], dtype=np.float32)
        if arr.ndim >= 2:
            q, scale = quantize_weight(arr)
            out[name] = {"kind": "int8", "values": _encode(q),
                         "scale": _encode(scale)}
        else:
            out[name] = {"kind": "raw", "values": _encode(arr)}
    return {"scheme": QUANT_SCHEME, "params": out}


def validate_quantized(blob) -> None:
    """Hard-validate a quantized blob (scheme tag, record structure,
    payload sizes) — ``ProgramSpec.from_json`` runs this so a corrupt
    file raises at load, where loaders degrade, not at first trace."""
    if not isinstance(blob, dict) or blob.get("scheme") != QUANT_SCHEME:
        raise ValueError(
            f"unknown quantization scheme "
            f"{blob.get('scheme') if isinstance(blob, dict) else blob!r} "
            f"(want {QUANT_SCHEME!r})")
    params = blob.get("params")
    if not isinstance(params, dict) or not params:
        raise ValueError("quantized blob has no 'params' dict")
    for name, doc in params.items():
        kind = doc.get("kind") if isinstance(doc, dict) else None
        if kind == "int8":
            q, scale = _decode(doc["values"]), _decode(doc["scale"])
            if q.dtype != np.int8 or scale.dtype != np.float32:
                raise ValueError(f"param {name!r}: int8 record carries "
                                 f"{q.dtype}/{scale.dtype}")
            if q.ndim < 2 or scale.shape != (q.shape[-1],):
                raise ValueError(f"param {name!r}: scale shape "
                                 f"{scale.shape} does not match values "
                                 f"{q.shape}")
        elif kind == "raw":
            _decode(doc["values"])
        else:
            raise ValueError(f"param {name!r}: unknown record kind "
                             f"{kind!r}")


def dequantize_params(blob: dict, dtype="float32") -> dict:
    """Quantized blob → ``{name: jnp array}``: int8 weights dequantized
    into the storage ``dtype``, raw entries (biases) as stored f32."""
    validate_quantized(blob)
    out = {}
    for name, doc in blob["params"].items():
        if doc["kind"] == "int8":
            out[name] = dequantize_weight(_decode(doc["values"]),
                                          _decode(doc["scale"]), dtype)
        else:
            out[name] = jnp.asarray(_decode(doc["values"]))
    return out


def quantize_program(spec, params: dict):
    """``(ProgramSpec, trained params)`` → a new spec with the int8
    weight payload embedded — the exportable v3-program form.

    Validates that ``params`` covers every parameter the spec's layers
    (plus the generator projection) read, so a wrong tree fails at
    export, not on the serving box.  ``canonical_dtype`` runs on the
    spec's storage dtype as a belt-and-braces check."""
    canonical_dtype(spec.dtype)
    required = set()
    if spec.role == "generator":
        required |= {"proj_w", "proj_b"}
    for le in spec.layers:
        required.add(le.w_param)
        if le.bias:
            required.add(le.b_param)
    missing = sorted(required - set(params))
    if missing:
        raise ValueError(f"params are missing {missing} required by "
                         f"program {spec.model}/{spec.role}")
    return dataclasses.replace(spec,
                               quantized_params=quantize_params(params))
