"""The storage-precision spec threaded through program resolution.

A precision names **one** thing: the dtype activations and weights are
*stored* in between layers (``float32`` / ``bfloat16`` / ``float16``).
It deliberately does not name an accumulator dtype — accumulation is
always float32, everywhere:

* the Pallas kernels (``kernels/ganax_conv.py``) accumulate tap
  contributions in an f32 VMEM scratch whatever the x/w block dtype,
  apply the fused epilogue to the f32 accumulator, and cast **once** at
  the flush store;
* the pure-JAX backends (``core/tconv.py`` / ``kernels/ref.py``)
  contract with ``preferred_element_type=float32`` and cast the result
  back to the input dtype, and :meth:`repro.core.dataflow.Epilogue
  .apply` runs the bias/activation math in f32 before casting back —
  so every backend computes the same function at every storage
  precision, and the f32 path is bit-identical to the pre-precision
  code.

int8 is *not* a storage dtype: int8 weights are a serialization format
(:mod:`repro.quant.weights`), dequantized into one of these storage
dtypes at program load.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["SUPPORTED_STORAGE_DTYPES", "Precision", "canonical_dtype",
           "storage_dtype", "storage_itemsize"]

SUPPORTED_STORAGE_DTYPES = ("float32", "bfloat16", "float16")

# Accepted spellings → canonical names.  Kept explicit (rather than
# np.dtype parsing) so an unsupported-but-parseable dtype like
# "float64" fails loudly instead of leaking into plan keys.
_ALIASES = {
    "float32": "float32", "f32": "float32", "fp32": "float32",
    "bfloat16": "bfloat16", "bf16": "bfloat16",
    "float16": "float16", "f16": "float16", "fp16": "float16",
    "half": "float16",
}

_JNP = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
        "float16": jnp.float16}


def canonical_dtype(dtype) -> str:
    """Canonical storage-dtype name of ``dtype`` (a name, alias, numpy
    dtype, or jax scalar type); raises ``ValueError`` for anything that
    is not a supported storage dtype."""
    if isinstance(dtype, str):
        name = dtype.strip().lower()
    else:
        name = np.dtype(dtype).name
    canon = _ALIASES.get(name)
    if canon is None:
        raise ValueError(
            f"unsupported storage dtype {dtype!r}; one of "
            f"{SUPPORTED_STORAGE_DTYPES} (aliases f32/bf16/f16)")
    return canon


def storage_dtype(dtype) -> np.dtype:
    """The concrete numpy dtype object of a storage-dtype name."""
    return jnp.dtype(_JNP[canonical_dtype(dtype)])


def storage_itemsize(dtype) -> int:
    """Bytes per element at storage precision — what byte accounting
    (HBM-traffic rows, sharding footprints) must use instead of a
    hardcoded 4."""
    return storage_dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class Precision:
    """Hashable precision spec: storage dtype + the (fixed) f32
    accumulator.  ``Precision("bf16")`` canonicalizes on construction,
    so two spellings of the same precision compare and hash equal."""

    storage: str = "float32"

    def __post_init__(self):
        object.__setattr__(self, "storage",
                           canonical_dtype(self.storage))

    @property
    def storage_dtype(self) -> np.dtype:
        return storage_dtype(self.storage)

    @property
    def accum_dtype(self) -> np.dtype:
        return jnp.dtype(jnp.float32)

    @property
    def itemsize(self) -> int:
        return self.storage_dtype.itemsize

    @property
    def is_f32(self) -> bool:
        return self.storage == "float32"

    def describe(self) -> str:
        return f"{self.storage} storage / float32 accumulate"
