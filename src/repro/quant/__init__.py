"""repro.quant — mixed-precision storage and int8 weight quantization.

The precision subsystem has three parts, each a module here:

* :mod:`repro.quant.precision` — the :class:`Precision` spec: which
  dtype activations and weights are *stored* in (``float32`` /
  ``bfloat16`` / ``float16``), with accumulation **always** float32.
  Programs carry the storage dtype (``GanConfig.dtype`` →
  ``ProgramSpec.dtype``); the kernels' f32 VMEM scratch and the
  pure-JAX backends' ``preferred_element_type=float32`` make every
  backend compute the same function regardless of storage precision.
* :mod:`repro.quant.weights` — per-channel symmetric int8 weight
  quantization as a **program-export transform**:
  :func:`quantize_program` embeds int8 tensors + f32 scales into a
  version-3 program JSON; :class:`repro.program.Program` dequantizes
  them into the storage dtype at load, so a planner-less serving
  process pays int8 disk/transfer cost with zero measurements.
* :mod:`repro.quant.tolerance` — checked-in per-Table-I-model output
  tolerance gates (bf16/f16/int8 vs the f32 reference), enforced by
  ``tests/test_quant.py`` so precision loss is validated, not vibes.
"""

from repro.quant.precision import (SUPPORTED_STORAGE_DTYPES, Precision,
                                   canonical_dtype, storage_dtype,
                                   storage_itemsize)
from repro.quant.tolerance import model_tolerance, op_tolerance
from repro.quant.weights import (dequantize_params, dequantize_weight,
                                 quantize_params, quantize_program,
                                 quantize_weight)

__all__ = [
    "SUPPORTED_STORAGE_DTYPES", "Precision", "canonical_dtype",
    "storage_dtype", "storage_itemsize", "model_tolerance",
    "op_tolerance", "dequantize_params", "dequantize_weight",
    "quantize_params", "quantize_program", "quantize_weight",
]
