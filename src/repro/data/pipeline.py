"""Data pipeline: deterministic synthetic streams + memmap token files.

Determinism contract (fault tolerance): batch contents are a pure function
of ``(seed, step)`` — a restarted job that resumes at step N sees exactly
the batches it would have seen, with no iterator state to checkpoint.

``Prefetcher`` overlaps host batch construction and device transfer with
the previous step's compute (queue depth 2 by default).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable

import jax
import numpy as np

from repro.configs.base import ArchConfig

__all__ = ["SyntheticLM", "MemmapTokens", "Prefetcher", "make_batch_fn"]


class SyntheticLM:
    """Zipf-ish synthetic token stream, pure function of (seed, step)."""

    def __init__(self, cfg: ArchConfig, batch: int, seq_len: int,
                 seed: int = 0, microbatches: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.micro = microbatches

    def __call__(self, step: int) -> dict:
        mix = (0x9E3779B97F4A7C15 * (step + 1)) % (1 << 64)
        rng = np.random.Philox(key=np.uint64(self.seed) ^ np.uint64(mix))
        gen = np.random.Generator(rng)
        shape = (self.batch, self.seq) if self.micro == 1 else \
            (self.micro, self.batch // self.micro, self.seq)
        # zipf-like marginal over the vocab, cheap to sample
        u = gen.random(shape)
        toks = np.minimum(
            (np.exp(u * np.log(self.cfg.vocab)) - 1).astype(np.int32),
            self.cfg.vocab - 1)
        batch = {"tokens": toks}
        if self.cfg.family == "vlm":
            img_shape = shape[:-1] + (self.cfg.img_tokens,
                                      self.cfg.frontend_dim)
            batch["img_embeds"] = gen.standard_normal(
                img_shape, dtype=np.float32)
        if self.cfg.family == "encoder":
            feat_shape = shape + (self.cfg.frontend_dim,)
            batch = {
                "features": gen.standard_normal(feat_shape,
                                                dtype=np.float32),
                "labels": gen.integers(0, self.cfg.vocab, shape,
                                       dtype=np.int32),
                "label_mask": (gen.random(shape) < 0.08).astype(np.float32),
            }
        return batch


class MemmapTokens:
    """Flat binary token file (uint16/uint32), deterministic slicing by
    step — the production input path (one shared file per host group)."""

    def __init__(self, path: str, cfg: ArchConfig, batch: int, seq_len: int,
                 dtype=np.uint16, microbatches: int = 1):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.cfg = cfg
        self.batch = batch
        self.seq = seq_len
        self.micro = microbatches
        self.tokens_per_step = batch * seq_len

    def __call__(self, step: int) -> dict:
        n = len(self.data)
        start = (step * self.tokens_per_step) % max(
            1, n - self.tokens_per_step)
        flat = np.asarray(self.data[start:start + self.tokens_per_step],
                          dtype=np.int32) % self.cfg.vocab
        shape = (self.batch, self.seq) if self.micro == 1 else \
            (self.micro, self.batch // self.micro, self.seq)
        return {"tokens": flat.reshape(shape)}


def make_batch_fn(source: Callable[[int], dict], shardings=None
                  ) -> Callable[[int], dict]:
    """Wrap a host batch source with device_put under the given shardings
    (pytree matching the batch dict or a single sharding for all)."""
    def fn(step: int) -> dict:
        host = source(step)
        if shardings is None:
            return jax.tree.map(jax.numpy.asarray, host)
        if isinstance(shardings, dict):
            return {k: jax.device_put(v, shardings.get(k))
                    for k, v in host.items()}
        return jax.tree.map(lambda v: jax.device_put(v, shardings), host)
    return fn


class Prefetcher:
    """Depth-k host-side prefetch: batch (step+i) builds while step runs."""

    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self.batch_fn = batch_fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._next
        while not self._stop.is_set():
            try:
                self.q.put((step, self.batch_fn(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def get(self) -> tuple[int, dict]:
        return self.q.get()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)
