"""AdamW (built from scratch) + LR schedules + global-norm clipping.

Optimizer moments are fp32 and carry their own shardings (ZeRO-1: extra
``data``-axis sharding via ``sharding.rules.opt_state_shardings``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "linear_warmup"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
                     0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
        return cfg.peak_lr * warm * frac
    return lr


def linear_warmup(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        return cfg.peak_lr * jnp.minimum(
            1.0, step.astype(jnp.float32) / jnp.maximum(1, cfg.warmup_steps))
    return lr


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_fn: Callable | None = None):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    lr_fn = lr_fn or cosine_schedule(cfg)
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_fn(count)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** count.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, stats
