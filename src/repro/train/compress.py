"""Gradient compression for cross-pod (DCN) traffic.

Int8 quantization with per-tensor scale and **error feedback** (the
residual of each step's quantization is added back before the next step's
quantization), applied as a ``grad_transform`` hook in
``make_train_step``.  In the SPMD setting the data-parallel all-reduce is
emitted by XLA inside backward; quantizing the *averaged* gradient models
the bandwidth-optimal reduce-scatter(int8)→all-gather(int8) schedule whose
numerics are what matters for convergence — the wire-format saving itself
is recorded in the roofline analysis (4× fewer DCN bytes on the pod axis).

``quantize_int8``/``dequantize_int8`` are also used by the serving engine
for KV-cache compression experiments.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ErrorFeedbackState",
           "make_int8_grad_transform"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_int8_grad_transform(params_template: Any):
    """Stateful (via closure ref) int8 compression with error feedback.

    Returns (transform, state_ref).  ``transform`` is pure w.r.t. jit when
    the error state is threaded through the train state — here we keep the
    simple emulation used by the convergence tests: quantize+dequantize
    with residual carried in the returned pytree (the caller threads it).
    """
    def transform_with_state(grads, err_state):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, s = quantize_int8(g32)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), g32 - deq
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err_state)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    def init_err():
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_template)

    return transform_with_state, init_err


class ErrorFeedbackState:
    """Convenience holder used by examples (non-jit path)."""

    def __init__(self, params_template):
        self.transform, init = make_int8_grad_transform(params_template)
        self.err = init()

    def __call__(self, grads):
        out, self.err = self.transform(grads, self.err)
        return out
