"""Fault-tolerant training loop.

Production posture (1000+ nodes):

* **Checkpoint/restart** — periodic async checkpoints; on any step failure
  the loop restores the latest checkpoint and *replays* from there (the
  data pipeline is a pure function of step, so replay is exact).
* **Preemption** — SIGTERM triggers a synchronous checkpoint then a clean
  exit (the standard TPU-pod eviction contract).
* **Straggler watchdog** — per-step wall time is tracked with an EWMA; a
  step slower than ``straggler_factor ×`` the EWMA fires a callback (on a
  real cluster: report the slow host for replacement / trigger
  data-rebalancing; here: logged + counted, and used by tests).
* **Failure injection** — ``failure_injector(step) -> bool`` lets tests
  and the elastic example kill arbitrary steps deterministically.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax

from repro import obs as _obs
from repro.program.spec import _UNSET as _MESH_UNSET
from repro.train import checkpoint as ckpt

__all__ = ["LoopConfig", "TrainLoop", "InjectedFailure",
           "make_gan_train_step"]


def make_gan_train_step(cfg, batch: int, *, g_lr: float = 2e-4,
                        d_lr: float | None = None, policy=None,
                        planner=None, measure: bool = False,
                        mesh=_MESH_UNSET):
    """Program-backed adversarial SGD step for a ``GanConfig``.

    Builds the generator and discriminator
    :class:`repro.program.Program` **once** — the whole
    config → policy → epilogue → plan walk happens here, ahead of the
    first trace — and returns ``(train_step, (g_program, d_program))``
    where ``train_step(state, batch)`` is a jitted
    ``((g_params, d_params), {"z", "real"}) → (state, metrics)`` that
    replays the frozen programs every step.  ``measure=True`` tunes
    plan misses at build for an ``auto`` policy (never during the
    loop).

    ``mesh`` (default: ``cfg.mesh``) builds **sharded** programs: the
    programs' forwards run under ``shard_map``, so the batch splits
    over the ``data`` axis and the weight cotangents are ``psum``-med
    across it by the shard_map transpose — data-parallel gradient
    reduction with no explicit ``pmean`` in the loss.  The returned
    step then ``device_put``s each incoming batch array with
    :func:`repro.sharding.rules.batch_sharding` (batch dim over
    ``data``), and exposes ``train_step.state_shardings`` — a
    ``(g, d)`` pair of replicated :func:`~repro.sharding.rules
    .param_shardings` trees — for placing the initial state and for
    :class:`TrainLoop`'s checkpoint-restore ``state_shardings``.
    Degrades with the programs: too few local devices → a plain
    single-device step.

    **Mixed precision** (``cfg.dtype="bfloat16"``/``"float16"``): the
    programs cast activations and weights to the storage dtype *at
    use* and accumulate in f32 (see ``repro.quant``), so parameters,
    optimizer state, and gradients stay f32 end to end — the
    ``state_shardings`` f32 shape-structs and checkpoints need no
    change, and the step stays numerically stable at low storage
    precision."""
    from repro.models.gan import bce_with_logits
    from repro.program import Program

    d_lr = g_lr if d_lr is None else d_lr
    g_prog = Program.build(cfg, batch, "generator", policy=policy,
                           planner=planner, measure=measure, mesh=mesh)
    d_prog = Program.build(cfg, batch, "discriminator", policy=policy,
                           planner=planner, measure=measure, mesh=mesh)

    def losses(g_params, d_params, z, real):
        fake = g_prog.forward(g_params, z)
        d_fake = d_prog.forward(d_params, fake)
        d_real = d_prog.forward(d_params, real)
        d_loss = bce_with_logits(d_real, 1.0) + \
            bce_with_logits(d_fake, 0.0)
        g_loss = bce_with_logits(d_fake, 1.0)
        return g_loss, d_loss

    @jax.jit
    def train_step(state, batch):
        g_params, d_params = state
        z, real = batch["z"], batch["real"]
        dl, d_grads = jax.value_and_grad(
            lambda d: losses(g_params, d, z, real)[1])(d_params)
        d_new = jax.tree.map(lambda p, g: p - d_lr * g, d_params,
                             d_grads)
        gl, g_grads = jax.value_and_grad(
            lambda g: losses(g, d_new, z, real)[0])(g_params)
        g_new = jax.tree.map(lambda p, g: p - g_lr * g, g_params,
                             g_grads)
        return (g_new, d_new), {"g_loss": gl, "d_loss": dl,
                                "loss": gl + dl}

    if g_prog.mesh is not None:
        from repro.models.gan import (discriminator_specs,
                                      generator_specs)
        from repro.sharding.rules import (Rules, batch_sharding,
                                          param_shardings)
        mesh_obj = g_prog.mesh

        # GAN data-parallel state is fully replicated (the programs'
        # own shard_map in_specs do the Cout splitting where frozen) —
        # a Rules table mapping every param axis to no mesh axis.
        dp_rules = Rules(table={"conv_in": None, "conv_out": None,
                                "mlp": None})

        def _shardings(specs):
            return param_shardings(
                mesh_obj, {k: s.axes for k, s in specs.items()},
                {k: jax.ShapeDtypeStruct(s.shape, "float32")
                 for k, s in specs.items()}, dp_rules)

        inner_step = train_step

        def train_step(state, batch):
            batch = {k: jax.device_put(
                         v, batch_sharding(mesh_obj,
                                           getattr(v, "ndim", 0)))
                     for k, v in batch.items()}
            return inner_step(state, batch)

        train_step.mesh = mesh_obj
        train_step.state_shardings = (_shardings(generator_specs(cfg)),
                                      _shardings(discriminator_specs(cfg)))
    else:
        train_step.mesh = None
        train_step.state_shardings = None
    return train_step, (g_prog, d_prog)


class InjectedFailure(RuntimeError):
    pass


def _collect_stats() -> dict:
    """External-subsystem stats through the obs registry's collector
    hooks — every dict is a fresh copy (``obs.collect``), so a snapshot
    held across the run never aliases live counter state.  The imports
    force collector registration (each module registers its own on
    import); missing subsystems simply don't report."""
    import repro.core.dataflow  # noqa: F401 — registers dataflow.uop_cache
    import repro.tune           # noqa: F401 — registers tune.planner
    return _obs.collect()


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    async_ckpt: bool = True
    max_restarts: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    log_every: int = 10


class TrainLoop:
    def __init__(self, cfg: LoopConfig, train_step: Callable,
                 batch_fn: Callable[[int], dict], state: Any,
                 state_shardings: Any = None,
                 failure_injector: Callable[[int], bool] | None = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.state = state
        self.state_shardings = state_shardings
        self.failure_injector = failure_injector
        self.log = log_fn
        self.restarts = 0
        self._last_saved_step: int | None = None
        self.straggler_events: list[int] = []
        self._ewma: float | None = None
        self._preempted = False
        self.metrics_history: list[dict] = []

    # -- signals ------------------------------------------------------------
    def _install_sigterm(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on the main thread (tests)

    # -- checkpointing -------------------------------------------------------
    def _save(self, step: int, sync: bool = False):
        if sync or not self.cfg.async_ckpt:
            ckpt.save(self.state, self.cfg.ckpt_dir, step)
        else:
            ckpt.save_async(self.state, self.cfg.ckpt_dir, step)
        self._last_saved_step = step
        _obs.counter("train.checkpoints").inc()
        _obs.event("train.checkpoint", step=step,
                   sync=bool(sync or not self.cfg.async_ckpt))

    def _restore_latest(self) -> int:
        ckpt.wait_pending()
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            # replay is only exact from the step-0 parameters, not from
            # whatever partially-trained state the failure left behind
            self.state = self._initial_state
            self.log("[loop] no checkpoint found; restarting from step 0")
            return 0
        self.state = ckpt.restore(self.state, self.cfg.ckpt_dir, step,
                                  self.state_shardings)
        self.log(f"[loop] restored checkpoint at step {step}")
        _obs.event("train.restore", step=step)
        return step

    # -- watchdog -----------------------------------------------------------
    def _watch(self, step: int, dt: float):
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma:
            self.straggler_events.append(step)
            _obs.counter("train.stragglers").inc()
            _obs.event("train.straggler", step=step, dt_s=dt,
                       ewma_s=self._ewma)
            self.log(f"[loop] STRAGGLER step {step}: {dt:.3f}s vs "
                     f"EWMA {self._ewma:.3f}s")
        self._ewma = (1 - self.cfg.ewma_alpha) * self._ewma + \
            self.cfg.ewma_alpha * dt

    # -- main ---------------------------------------------------------------
    def run(self, start_step: int = 0) -> Any:
        self._install_sigterm()
        self._stats0 = _collect_stats()
        self._initial_state = self.state  # immutable tree: reference only
        step_us = _obs.histogram("train.step_us")
        step = start_step
        while step < self.cfg.total_steps:
            if self._preempted:
                self.log(f"[loop] SIGTERM: checkpointing at {step}, exiting")
                _obs.event("train.preempt", step=step)
                self._save(step, sync=True)
                self._log_uop_cache()
                return self.state
            try:
                if self.failure_injector and self.failure_injector(step):
                    raise InjectedFailure(f"injected failure at step {step}")
                t0 = time.perf_counter()
                with _obs.trace("train.step", step=step):
                    batch = self.batch_fn(step)
                    self.state, metrics = self.train_step(self.state,
                                                          batch)
                    jax.block_until_ready(
                        jax.tree.leaves(self.state)[0])
                dt = time.perf_counter() - t0
                step_us.observe(dt * 1e6)
                _obs.counter("train.steps").inc()
                self._watch(step, dt)
                if step % self.cfg.log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()
                         if getattr(v, "ndim", 0) == 0}
                    for k, v in m.items():
                        _obs.gauge(f"train.{k}").set(v)
                    self.metrics_history.append({"step": step, **m})
                    self.log(f"[loop] step {step} "
                             f"loss={m.get('total_loss', m.get('loss', -1)):.4f} "
                             f"dt={dt:.3f}s")
                step += 1
                if step % self.cfg.ckpt_every == 0:
                    self._save(step)
            except InjectedFailure as e:
                self.restarts += 1
                _obs.counter("train.failures").inc()
                _obs.event("train.failure", step=step,
                           restart=self.restarts)
                self.log(f"[loop] FAILURE: {e}; restart "
                         f"{self.restarts}/{self.cfg.max_restarts}")
                if self.restarts > self.cfg.max_restarts:
                    raise
                step = self._restore_latest()
        # drain in-flight async saves; *this run* already checkpointed the
        # final step when total_steps is a multiple of ckpt_every (a stale
        # file from an earlier run in the same dir doesn't count)
        ckpt.wait_pending()
        if self._last_saved_step != self.cfg.total_steps:
            self._save(self.cfg.total_steps, sync=True)
        self._log_uop_cache()
        return self.state

    def _log_uop_cache(self):
        """Surface the dataflow μop-cache efficiency over this run:
        replayed/retraced steps should hit the cache, not re-run the
        scheduler.  Both sources are read through ``obs.collect()``
        (consistent copies), never by poking subsystem privates."""
        stats = _collect_stats()
        info = stats.get("dataflow.uop_cache")
        if info is not None:
            base = self._stats0.get("dataflow.uop_cache",
                                    {"hits": 0, "misses": 0})
            hits = info["hits"] - base["hits"]
            misses = info["misses"] - base["misses"]
            if hits or misses:
                self.log(f"[loop] dataflow μop cache: {hits} hits / "
                         f"{misses} misses this run "
                         f"({info['currsize']} geometries cached)")
        tune = stats.get("tune.planner")
        if tune is not None:
            base = self._stats0.get("tune.planner") or \
                {"lookups": 0, "hits": 0, "measurements": 0}
            lookups = tune["lookups"] - base["lookups"]
            if lookups:
                self.log(f"[loop] tune planner: {lookups} lookups / "
                         f"{tune['hits'] - base['hits']} plan hits / "
                         f"{tune['measurements'] - base['measurements']} "
                         f"measurements this run "
                         f"({tune['plans']} plans cached)")
