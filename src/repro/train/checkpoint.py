"""Sharded, atomic, async, *elastic* checkpointing.

Layout (one directory per step)::

    <dir>/step_000123/
        meta.json            # treedef paths, shapes, dtypes, mesh, step
        arrays/<leaf-key>.npy

Design points for the 1000-node posture:

* **Atomicity** — written to ``step_N.tmp`` then ``os.rename``'d; a crash
  mid-save never corrupts the latest checkpoint.
* **Async** — ``save_async`` snapshots device arrays to host then writes on
  a background thread; training continues immediately.
* **Elasticity** — the checkpoint stores *global* arrays + logical
  PartitionSpecs.  ``restore`` re-shards onto whatever mesh the restoring
  job has (tested: save on a (4,2) mesh, restore on (2,2) or (8,)).
* **Multi-host** — on a real cluster each host writes only
  ``arr.addressable_shards`` (key includes the shard index) and restore
  uses ``make_array_from_single_device_arrays``; the single-process path
  here writes full arrays, the code seam is ``_gather_for_save``.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "all_steps"]

_SEP = "::"


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def _gather_for_save(arr) -> np.ndarray:
    # Single-process: materialize the global array.  Multi-host seam:
    # replace with per-shard writes of arr.addressable_shards.
    return np.asarray(jax.device_get(arr))


def save(state, ckpt_dir: str, step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "arrays"))
    flat = _flatten(state)
    meta = {"step": int(step), "keys": {}}
    for key, leaf in flat.items():
        arr = _gather_for_save(leaf)
        fn = re.sub(r"[^A-Za-z0-9_.:-]", "_", key)
        np.save(os.path.join(tmp, "arrays", fn + ".npy"), arr)
        meta["keys"][key] = {"file": fn + ".npy",
                             "shape": list(arr.shape),
                             "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_pending: list[threading.Thread] = []


def save_async(state, ckpt_dir: str, step: int) -> threading.Thread:
    """Snapshot to host synchronously, write to disk on a thread."""
    host_state = jax.tree.map(_gather_for_save, state)
    t = threading.Thread(target=save, args=(host_state, ckpt_dir, step),
                         daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_pending():
    for t in _pending:
        t.join()
    _pending.clear()


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(template, ckpt_dir: str, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — this is where *elastic resharding* happens: the saved
    global arrays are simply device_put with the new mesh's shardings.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, info in meta["keys"].items():
        if key not in flat_t:
            continue  # allow restoring subsets (elastic arch evolution)
        arr = np.load(os.path.join(d, "arrays", info["file"]))
        tmpl = flat_t[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        if key in flat_s:
            out[key] = jax.device_put(arr, flat_s[key])
        else:
            out[key] = jax.device_put(arr)
    missing = set(flat_t) - set(out)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}…")
    # unflatten by path
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    ordered = []
    for path, _ in leaves:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), ordered)
