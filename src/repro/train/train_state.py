"""Train state assembly and the jitted train step (with microbatching)."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tr
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule)

__all__ = ["init_train_state", "make_train_step"]


def init_train_state(cfg: ArchConfig, key: jax.Array,
                     opt_cfg: AdamWConfig | None = None) -> dict:
    params = tr.init(cfg, key)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    flags: tr.RunFlags = tr.RunFlags(),
                    grad_accum: int = 1,
                    grad_transform: Callable | None = None,
                    compute_shardings=None, master_shardings=None):
    """Build the (jittable) train step.

    ``grad_accum > 1``: the batch leaves carry a leading microbatch axis
    (A, mb, ...) and gradients accumulate across a ``lax.scan`` — memory
    scales with the microbatch, not the global batch.
    ``grad_transform``: optional hook applied to the mean gradients (e.g.
    int8 compression emulation, see train/compress.py).
    ``compute_shardings``: optional NamedSharding pytree pinned onto the
    bf16 compute copy of the params *outside* the accumulation scan — with
    FSDP-sharded master params this hoists the per-layer weight all-gather
    out of the microbatch loop (once per step instead of once per
    microbatch; −8× FSDP gather traffic at grad_accum=8, §Perf HC5).
    """
    lr_fn = cosine_schedule(opt_cfg)

    def loss(params, mb):
        total, metrics = tr.loss_fn(params, mb, cfg, flags)
        return total, metrics

    def train_step(state, batch):
        master = state["params"]
        if compute_shardings is not None:
            # differentiate wrt a bf16 TP-only-sharded compute copy
            # (gathers hoisted out of the accumulation scan) but keep the
            # fp32 FSDP-sharded master for the optimizer
            params = jax.lax.with_sharding_constraint(
                tr._cast_params(master, cfg.activation_dtype),
                compute_shardings)
        else:
            params = master
        if grad_accum == 1:
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
        else:
            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(
                    loss, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), metrics
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, l), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            l = l / grad_accum
            metrics = jax.tree.map(
                lambda m: m.mean() if m.ndim else m, metrics)
        if grad_transform is not None:
            grads = grad_transform(grads)
        if master_shardings is not None:
            # reduce-scatter grads back to the master (FSDP) layout
            grads = jax.lax.with_sharding_constraint(grads,
                                                     master_shardings)
        new_params, new_opt, stats = adamw_update(
            master, grads, state["opt"], opt_cfg, lr_fn)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["total_loss"] = l
        return new_state, metrics

    return train_step
