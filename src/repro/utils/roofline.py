"""Three-term roofline model (TPU v5e) from dry-run artifacts.

Terms (seconds per step, per device — the HLO module is the per-device
program, verified against unrolled references in tests/test_hlo.py):

  compute    = HLO_dot/conv_FLOPs  / 197e12        (bf16 peak per chip)
  memory     = HLO HBM bytes       / 819e9         (HBM bandwidth)
  collective = ICI bytes (ring-adjusted) / 50e9    (per-link ICI, 1 link
               conservatively; all-reduce counted 2× for ring schedules)
  dcn        = pod-crossing bytes / 6.25e9         (multi-pod only)

``MODEL_FLOPS`` = 6·N·D (dense) / 6·N_active·D (MoE), computed analytically
from the config; the ratio MODEL/HLO flags remat & dispatch waste.  The
headline "roofline fraction" is MODEL-compute-time / max(term) — the MFU
upper bound the compiled program permits.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (conservative: 1 link)
DCN_BW = 6.25e9          # bytes/s per chip across pods (assumed)

__all__ = ["RooflineRow", "analyze_artifact", "load_rows", "ARTIFACT_DIR"]

ARTIFACT_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"))


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    reason: str = ""
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dcn_s: float = 0.0
    dominant: str = ""
    model_flops_global: float = 0.0
    hlo_flops_global: float = 0.0
    useful_ratio: float = 0.0
    mfu_bound: float = 0.0
    temp_gb: float = 0.0
    compile_s: float = 0.0
    note: str = ""

    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s,
                   self.dcn_s)


_MOVE_NOTE = {
    "compute": "reduce recompute (remat policy) / skip masked causal work",
    "memory": "shrink resident working set (int8 cache, smaller dispatch "
              "buffers, fused one-hot)",
    "collective": "reshard to cut per-layer gathers / overlap with compute"
                  " (collective matmul)",
    "dcn": "compress pod-crossing gradients (int8 + error feedback)",
}


def analyze_artifact(art: dict) -> RooflineRow:
    if art.get("status") != "ok":
        return RooflineRow(arch=art["arch"], shape=art["shape"],
                           mesh=art.get("mesh", "?"),
                           status=art.get("status", "error"),
                           reason=art.get("reason", art.get("error", "")))
    hp = art["hlo_parsed"]
    n_dev = art["n_devices"]
    coll = hp["collective_bytes"]
    ring_adjusted = sum(v * (2.0 if k == "all-reduce" else 1.0)
                        for k, v in coll.items())
    dcn = hp.get("collective_dcn_bytes", 0.0)
    ici = max(0.0, ring_adjusted - 2.0 * dcn)
    meta = art["meta"]
    model_flops = meta["model_flops_per_token"] * meta["tokens_per_step"]
    hlo_global = hp["flops"] * n_dev
    row = RooflineRow(
        arch=art["arch"], shape=art["shape"], mesh=art["mesh"], status="ok",
        compute_s=hp["flops"] / PEAK_FLOPS,
        memory_s=hp["bytes"] / HBM_BW,
        collective_s=ici / ICI_BW,
        dcn_s=dcn / DCN_BW,
        model_flops_global=model_flops,
        hlo_flops_global=hlo_global,
        useful_ratio=model_flops / hlo_global if hlo_global else 0.0,
        temp_gb=(art["memory_analysis"]["temp_bytes"] or 0) / 2**30,
        compile_s=art.get("compile_s", 0.0),
    )
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s, "dcn": row.dcn_s}
    row.dominant = max(terms, key=terms.get)
    model_time = (model_flops / n_dev) / PEAK_FLOPS
    row.mfu_bound = model_time / row.bound_s() if row.bound_s() else 0.0
    row.note = _MOVE_NOTE[row.dominant]
    return row


def load_rows(artifact_dir: str | None = None, variant: str = ""
              ) -> list[RooflineRow]:
    d = artifact_dir or ARTIFACT_DIR
    rows = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("_")
        is_variant = len(parts) > 3 and parts[-1] not in ("16x16",
                                                          "2x16x16")
        if bool(variant) != is_variant:
            continue
        if variant and not base.endswith("_" + variant):
            continue
        with open(path) as f:
            rows.append(analyze_artifact(json.load(f)))
    return rows
