"""HLO text cost model: FLOPs, memory bytes, collective bytes — with
``while``-loop trip-count multipliers.

Why: ``compiled.cost_analysis()`` counts a while-loop body **once** (we
verified experimentally: a 32-layer scanned transformer reports ~1/32 of
its true FLOPs), and provides no per-collective breakdown at all.  Since
every repeated layer stack in this codebase is a ``lax.scan`` (compile-time
hygiene), an accurate roofline *requires* walking the call graph with trip
counts — XLA records them in ``backend_config={"known_trip_count":{"n":…}}``.

Model:
* FLOPs: ``dot`` (2·|result|·contracted) and ``convolution``
  (2·|result|·K_spatial·C_in/group) ops only — matmul-class work dominates;
  elementwise FLOPs inside fusions are ignored (they are bandwidth-, not
  compute-bound).
* Memory bytes: per top-level op, |result| + Σ|operands| — post-fusion HLO
  granularity approximates HBM traffic (fusion internals stay in
  registers/VMEM).
* Collectives: per kind, bytes = max(|operands|, |result|) per instruction
  (shard-view), multiplied through loops; all-reduce wire bytes ≈ 2× this
  for ring algorithms — reported raw, the roofline applies the algorithm
  factor.  Each collective is tagged ICI vs DCN ("pod"-crossing) by the
  device-id span of its replica groups.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "s4": 0.5, "u4": 0.5, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# first opcode-like token followed by '(' — robust to tuple types with
# /*index=N*/ comments and layout annotations
_INSTR_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


class _OpMatch:
    __slots__ = ("_groups",)

    def __init__(self, groups):
        self._groups = groups

    def groups(self):
        return self._groups

    def group(self, i):
        return self._groups[i - 1]


class _OpRe:
    """Drop-in for the old regex: returns (name, type_str, instr, rest)."""

    @staticmethod
    def match(line: str):
        m = _ASSIGN_RE.match(line)
        if not m:
            return None
        name, rhs = m.groups()
        im = _INSTR_RE.search(rhs)
        if not im:
            return None
        ty = rhs[: im.start()]
        instr = im.group(1)
        rest = rhs[im.end():]
        return _OpMatch((name, ty, instr, rest))


_OP_RE = _OpRe()

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_dcn: float = 0.0
    calls: list[tuple[str, float]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HloCost:
    """Aggregated per-device cost of the compiled module."""
    flops: float
    bytes: float
    collective_bytes: dict[str, float]
    collective_dcn_bytes: float
    n_collectives: dict[str, int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_dcn_bytes": self.collective_dcn_bytes,
            "n_collectives": dict(self.n_collectives),
        }


def _parse_operands(rest: str) -> list[str]:
    """Operand names from the text after the opening paren."""
    depth = 1
    out = []
    cur = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth == 1 and ch == ",":
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    names = []
    for tok in out:
        m = re.search(r"%([\w.\-]+)", tok)
        if m:
            names.append(m.group(1))
    return names


def _crosses_pod(line: str, pod_stride: int) -> bool:
    m = re.search(r"replica_groups=\{(\{.*?\})\}", line) or \
        re.search(r"replica_groups=\{([^{}]*)\}", line)
    if m:
        groups = m.group(1)
        ids = [int(x) for x in re.findall(r"\d+", groups)]
    else:
        m = re.search(r"replica_groups=\[\d+,\d+\]<=\[([\d,TS()]*)\]", line)
        # iota format [G,N]<=[dims] — conservative: check the product span
        ids = None
    if m is None:
        return False
    if ids is None:
        # iota replica groups: e.g. [2,256]<=[512] or <=[16,2,16]T(1,0,2)
        m2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if not m2:
            return False
        g, n = int(m2.group(1)), int(m2.group(2))
        # a group spanning >= pod_stride consecutive-range devices may
        # cross; precise check needs the permutation — be conservative:
        return g * n > pod_stride and n > 1 and _iota_spans_pod(
            line, pod_stride)
    return any(len({i // pod_stride for i in grp}) > 1
               for grp in _split_groups(m.group(1)))


def _split_groups(s: str) -> list[list[int]]:
    return [[int(x) for x in re.findall(r"\d+", g)]
            for g in re.findall(r"\{([^{}]*)\}", "{" + s + "}")
            ] or [[int(x) for x in re.findall(r"\d+", s)]]


def _iota_spans_pod(line: str, pod_stride: int) -> bool:
    """Decode iota replica groups `[G,N]<=[dims]T(perm)` and test whether
    any group contains ids from different pods (id // pod_stride)."""
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
        line)
    if not m:
        return True  # unknown — assume worst case
    g, n, dims_s, perm_s = m.groups()
    g, n = int(g), int(n)
    dims = [int(x) for x in dims_s.split(",")]
    total = math.prod(dims)
    ids = list(range(total))
    if perm_s:
        perm = [int(x) for x in perm_s.split(",")]
        # reshape to dims, transpose by perm, flatten
        import numpy as np
        ids = list(np.arange(total).reshape(dims).transpose(perm).ravel())
    for gi in range(g):
        grp = ids[gi * n:(gi + 1) * n]
        pods = {i // pod_stride for i in grp}
        if len(pods) > 1:
            return True
    return False


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "call",
    "conditional", "custom-call",
}

# ops that read only the bytes they produce (slicing/expansion), not their
# full operands — counting full operands would wildly overcount scan-body
# parameter slicing (full stacked weights × trip count).
_RESULT_ONLY_OPS = {
    "dynamic-slice", "slice", "gather", "broadcast", "reshape", "reverse",
    "pad", "concatenate",
    # elementwise ops: the CPU backend materializes them standalone, but
    # TPU fuses them into producers/consumers — count one tensor's worth
    # (the result) instead of result+operands to avoid systematically
    # double-counting every op chain (validated: keeps the scan-vs-unroll
    # equivalence in tests/test_hlo.py).
    "convert", "multiply", "add", "subtract", "divide", "maximum",
    "minimum", "negate", "exponential", "tanh", "rsqrt", "sqrt", "log",
    "select", "compare", "and", "or", "xor", "not", "power", "abs",
    "sign", "floor", "ceil", "clamp", "round-nearest-even",
    "round-nearest-afz", "exponential-minus-one", "log-plus-one",
}


def analyze_hlo(hlo_text: str, pod_stride: int = 1 << 62) -> HloCost:
    """Parse optimized HLO text into per-device cost terms."""
    # Pass 1: op name → result type string (module-wide; names are unique),
    # plus raw lines per computation and each computation's sliced params
    # (parameters consumed only through slicing ops — their true read
    # volume is ~the slice, not the buffer).
    shapes: dict[str, str] = {}
    comp_lines: dict[str, list[str]] = {}
    entry: str | None = None
    cur_lines: list[str] | None = None
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            is_entry, name = cm.groups()
            cur_lines = []
            comp_lines[name] = cur_lines
            if is_entry:
                entry = name
            continue
        m = _OP_RE.match(line)
        if m:
            name, ty, _, _ = m.groups()
            shapes[name] = ty
            if cur_lines is not None:
                cur_lines.append(line)

    # parameter-number map + sliced-param detection per computation
    sliced_params: dict[str, set[int]] = {}
    param_no: dict[str, dict[str, int]] = {}
    for cname, lines in comp_lines.items():
        pnos: dict[str, int] = {}
        for line in lines:
            m = _OP_RE.match(line)
            if m and m.group(3) == "parameter":
                pm = re.search(r"parameter\((\d+)", line)
                if pm:
                    pnos[m.group(1)] = int(pm.group(1))
        param_no[cname] = pnos
        sliced: set[int] = set()
        for line in lines:
            m = _OP_RE.match(line)
            if m and m.group(3) in ("dynamic-slice", "slice", "gather"):
                for o in _parse_operands(m.group(4)):
                    if o in pnos:
                        sliced.add(pnos[o])
        sliced_params[cname] = sliced

    # computations that are pure elementwise chains (CPU wraps every
    # elementwise op in a kLoop fusion; TPU would fuse them into
    # producers/consumers → count result bytes only)
    _EW_OK = _RESULT_ONLY_OPS | {"parameter", "constant", "tuple",
                                 "get-tuple-element", "iota", "copy",
                                 "bitcast"}
    elementwise_comps: set[str] = set()
    for cname, lines in comp_lines.items():
        ops = [m.group(3) for m in (
            _OP_RE.match(l) for l in lines) if m]
        if ops and all(o in _EW_OK for o in ops):
            elementwise_comps.add(cname)

    # Pass 2: per-computation costs.
    comps: dict[str, _CompCost] = {}
    for cur_name, lines in comp_lines.items():
        cur = _CompCost()
        comps[cur_name] = cur
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            name, ty, instr, rest = m.groups()
            _analyze_op(cur, name, ty, instr, rest, line, shapes,
                        sliced_params, elementwise_comps, pod_stride)
    # fallthrough to pass 3 below
    return _resolve(comps, entry)


def _analyze_op(cur, name, ty, instr, rest, line, shapes, sliced_params,
                elementwise_comps, pod_stride):
        result_bytes = _shape_bytes(ty)
        operands = _parse_operands(rest)
        operand_bytes = sum(_shape_bytes(shapes.get(o, ""))
                            for o in operands)

        if instr == "dot":
            dt, rdims = _first_shape_dims(ty)
            lhs_ty = shapes.get(operands[0], "") if operands else ""
            _, ldims = _first_shape_dims(lhs_ty)
            cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            contracted = 1
            if cd and ldims:
                for idx in cd.group(1).split(","):
                    if idx:
                        contracted *= ldims[int(idx)]
            cur.flops += 2.0 * math.prod(rdims or [0]) * contracted
        elif instr == "convolution":
            _, rdims = _first_shape_dims(ty)
            rhs_ty = shapes.get(operands[1], "") if len(operands) > 1 else ""
            _, kdims = _first_shape_dims(rhs_ty)
            dl = re.search(r"dim_labels=\w+_(\w+)->", rest)
            k_contract = 1
            if dl and kdims:
                rhs_labels = dl.group(1)
                for pos, ch in enumerate(rhs_labels):
                    if ch != "o":       # spatial dims + 'i'
                        k_contract *= kdims[pos]
            cur.flops += 2.0 * math.prod(rdims or [0]) * k_contract
        elif instr.removesuffix("-start") in COLLECTIVES and \
                not instr.endswith("-done"):
            kind = instr.removesuffix("-start")
            moved = max(result_bytes, operand_bytes)
            cur.coll[kind] += moved
            cur.coll.setdefault(kind + "_count", 0)
            cur.coll[kind + "_count"] += 1
            if _crosses_pod(line, pod_stride):
                cur.coll_dcn += moved

        if instr == "while":
            tc = re.search(r'"known_trip_count"\s*:\s*\{"n":"(\d+)"\}', line)
            n = float(tc.group(1)) if tc else 1.0
            body = re.search(r"body=%?([\w.\-]+)", rest)
            cond = re.search(r"condition=%?([\w.\-]+)", rest)
            if body:
                cur.calls.append((body.group(1), n))
            if cond:
                cur.calls.append((cond.group(1), n))
        elif instr in ("call", "async-start"):
            cal = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", rest)
            if cal:
                cur.calls.append((cal.group(1), 1.0))
        elif instr == "conditional":
            for cal in re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"true_computation=%?([\w.\-]+)|"
                    r"false_computation=%?([\w.\-]+))", rest):
                for c in cal:
                    for nm in re.findall(r"%?([\w.\-]+)", c):
                        if nm in ("",):
                            continue
                        cur.calls.append((nm, 1.0))
        elif instr == "fusion":
            pass  # internals don't touch HBM; dot-fusions not emitted here

        if instr == "dynamic-update-slice":
            # read-modify-write of the updated region only (in-place alias)
            upd = _shape_bytes(shapes.get(operands[1], "")) \
                if len(operands) > 1 else 0.0
            cur.bytes += 2 * upd
        elif instr in _RESULT_ONLY_OPS:
            cur.bytes += result_bytes
        elif instr == "fusion":
            cal = re.search(r"calls=%?([\w.\-]+)", rest)
            if cal and cal.group(1) in elementwise_comps:
                cur.bytes += result_bytes   # TPU fuses elementwise chains
                return
            sliced = sliced_params.get(cal.group(1), set()) if cal else set()
            b = result_bytes
            for j, o in enumerate(operands):
                ob = _shape_bytes(shapes.get(o, ""))
                if j in sliced:
                    ob = min(ob, result_bytes)  # reads ~a slice of it
                b += ob
            cur.bytes += b
        elif instr not in _SKIP_BYTES_OPS and instr != "while":
            cur.bytes += result_bytes + operand_bytes


def _resolve(comps, entry):
    # Pass 3: resolve call graph from ENTRY with multipliers.
    memo: dict[str, tuple[float, float, dict, float, dict]] = {}

    def resolve(name: str) -> tuple[float, float, dict, float, dict]:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, {}, 0.0, {})
        memo[name] = (0.0, 0.0, {}, 0.0, {})  # cycle guard
        flops, byts = c.flops, c.bytes
        coll = {k: v for k, v in c.coll.items()}
        dcn = c.coll_dcn
        for callee, mult in c.calls:
            cf, cb, cc, cd, _ = resolve(callee)
            flops += mult * cf
            byts += mult * cb
            dcn += mult * cd
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + mult * v
        memo[name] = (flops, byts, coll, dcn, {})
        return memo[name]

    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    flops, byts, coll, dcn, _ = resolve(entry)
    counts = {k[:-6]: int(v) for k, v in coll.items()
              if k.endswith("_count")}
    coll = {k: v for k, v in coll.items() if not k.endswith("_count")}
    return HloCost(flops=flops, bytes=byts, collective_bytes=coll,
                   collective_dcn_bytes=dcn, n_collectives=counts)
