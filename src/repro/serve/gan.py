"""Batched GAN image-generation service on ahead-of-time compiled
programs.

The serving analogue of `serve.engine.DecodeEngine` for the GAN
workloads.  On construction the server builds (or is handed) one
:class:`repro.program.Program`: the config → policy → epilogue → plan
walk happens exactly once, ahead of the first trace, and the hot path is
the program's single jitted executable — there is no per-request (or
even per-trace) resolution left.  With ``backend="auto"`` the build
**measures** a plan for every generator-layer geometry (zero
measurements when the planner's plan file is already warm); the frozen
per-layer resolutions are exposed via ``server.describe()`` and the
one-line summary in ``repr``.  A program exported from a tuning box
(``ProgramSpec.save``) can be served directly by passing ``program=``.

``generate(n)`` rounds work up to full batches but **discards nothing**:
tail samples beyond ``n`` are carried in a remainder buffer and served
first on the next call, so under ``n % batch_size != 0`` traffic every
generated sample is eventually served.  ``samples_served`` /
``samples_buffered`` / ``samples_discarded`` account for every sample
the generator produced (``samples_discarded`` stays 0 while the buffer
carries remainders; it exists so capacity planning can trust the
invariant ``served + buffered + discarded == batches x batch_size``).

Two ways to drive it:

* **Synchronous** — call ``generate(n)`` from one thread; the call
  blocks until the samples are on the host.
* **Asynchronous** — call ``submit(n)`` (from any number of threads):
  the first ``submit`` hands the server's program, RNG key, and
  remainder buffer to an internal continuous-batching
  :class:`~repro.serve.gan_engine.GanEngine`, and returns a
  :class:`~repro.serve.gan_engine.GanFuture`.  From then on
  ``generate`` delegates to the engine too (``submit(n).result()``), so
  the sample stream stays single-sourced and bit-identical to the
  synchronous one at equal seeds.  Call ``close()`` (or use the server
  as a context manager) to shut the engine down cleanly.

For many concurrent clients, batch-size buckets, and measured
throughput/latency, construct a :class:`~repro.serve.gan_engine
.GanEngine` directly — see ``docs/serving.md``.
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import jax
import numpy as np

from repro import obs as _obs
from repro.core.dataflow import DataflowPolicy
from repro.models.gan import GanConfig
from repro.program import Program, ProgramSpec
from repro.program.spec import _UNSET as _MESH_UNSET

__all__ = ["GanServer"]

# Distinguishes the metrics of multiple servers in one process (same
# model, different seeds/batch sizes) — the label, not the metric name,
# carries the instance identity.
_SERVER_SEQ = itertools.count()

# Batch occupancy is a fraction of batch_size in (0, 1]; latency buckets
# make no sense for it.
_OCCUPANCY_BOUNDS = tuple(i / 10 for i in range(1, 11))


class GanServer:
    def __init__(self, cfg: GanConfig, g_params, batch_size: int = 8,
                 policy: DataflowPolicy | None = None, seed: int = 0,
                 warm_plans: bool = True,
                 program: Program | None = None, mesh=_MESH_UNSET,
                 dtype: str | None = None):
        if int(batch_size) <= 0:
            raise ValueError(f"batch_size must be positive, "
                             f"got {batch_size}")
        if dtype is not None:
            # serving-time storage-precision override (canonicalized by
            # GanConfig; accumulation stays f32 — see repro.quant)
            cfg = dataclasses.replace(cfg, dtype=dtype)
        self.cfg = cfg
        if g_params is None:
            # int8-deploy flow: a quantized program carries its own
            # (dequantized-at-load) parameters
            if program is None or not program.quantized:
                raise ValueError("g_params=None needs a quantized "
                                 "program= (int8 export) to serve")
            g_params = program.params
        self.params = g_params
        self.batch_size = int(batch_size)
        self.policy = policy or cfg.policy
        self.key = jax.random.PRNGKey(seed)
        # Accounting lives on the obs registry (one labeled metric set
        # per server instance); the old integer attributes survive as
        # read-only properties over these, so
        # ``served + buffered + discarded == batches × batch_size``
        # is now an invariant of registry state.
        self.server_id = f"{cfg.name}#{next(_SERVER_SEQ)}"
        labels = {"server": self.server_id}
        self._m_batches = _obs.counter("serve.batches", **labels)
        self._m_served = _obs.counter("serve.samples_served", **labels)
        self._m_discarded = _obs.counter("serve.samples_discarded",
                                         **labels)
        self._m_buffered = _obs.gauge("serve.samples_buffered", **labels)
        self._m_request_us = _obs.histogram("serve.request_us", **labels)
        self._m_occupancy = _obs.histogram(
            "serve.batch_occupancy", bounds=_OCCUPANCY_BOUNDS, **labels)
        self._spare: np.ndarray | None = None   # carried tail samples
        self._engine = None     # async façade (created on first submit)
        if program is not None:
            if program.spec.role != "generator":
                raise ValueError(f"GanServer needs a generator program, "
                                 f"got role={program.spec.role!r}")
            if dtype is None and program.spec.dtype != cfg.dtype:
                # adopt the exported program's storage precision unless
                # the caller pinned one explicitly
                cfg = dataclasses.replace(cfg, dtype=program.spec.dtype)
                self.cfg = cfg
            # a mismatched program file must fail here with a clear
            # error, not as a shape mismatch inside the first trace
            # (the heuristic-policy walk below touches no planner)
            expected = ProgramSpec.build(cfg, self.batch_size,
                                         "generator",
                                         policy=DataflowPolicy())
            if program.spec.geometry_signature() != \
                    expected.geometry_signature():
                raise ValueError(
                    f"program {program.spec.model!r} froze a different "
                    f"workload than config {cfg.name!r} builds "
                    f"(topology / z_dim / channel-scale / epilogue / "
                    f"precision drift)")
            self.program = program
        else:
            # measure=warm_plans: an auto policy tunes every layer plan
            # ahead of the first trace (a no-op for concrete policies,
            # and zero measurements when the plan cache is warm)
            self.program = Program.build(
                cfg, self.batch_size, "generator", policy=self.policy,
                measure=warm_plans, differentiable=False, mesh=mesh)
        if self.program.mesh is not None and \
                self.batch_size % self.program.spec.mesh[0]:
            raise ValueError(
                f"batch_size {self.batch_size} does not divide over "
                f"the program's data axis of "
                f"{self.program.spec.mesh[0]} (mesh "
                f"{self.program.mesh_str})")
        # sharded programs want their input batch placed batch-split
        # over the data axis before dispatch (None = single device,
        # including the degraded-mesh case: skip the device_put)
        self._in_sharding = self.program.input_sharding
        self._generate = self.program.apply

    # -- accounting (registry-backed; attribute API preserved) --------------
    # Once the async façade is live, the engine continues the stream:
    # totals are the pre-handoff counts plus the engine's, so the
    # ``served + buffered + discarded == batches × batch_size``
    # invariant spans the handoff.
    @property
    def batches_served(self) -> int:
        eng = self._engine
        return self._m_batches.value + (eng.batches_served if eng else 0)

    @property
    def samples_served(self) -> int:
        eng = self._engine
        return self._m_served.value + (eng.samples_served if eng else 0)

    @property
    def samples_discarded(self) -> int:
        eng = self._engine
        return self._m_discarded.value + \
            (eng.samples_discarded if eng else 0)

    @property
    def samples_buffered(self) -> int:
        if self._engine is not None:
            return self._engine.samples_buffered
        return 0 if self._spare is None else len(self._spare)

    def _set_spare(self, spare: np.ndarray | None) -> None:
        self._spare = spare if spare is not None and len(spare) else None
        self._m_buffered.set(self.samples_buffered)

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # -- async façade -------------------------------------------------------
    def submit(self, n: int):
        """Asynchronous :meth:`generate`: enqueue a request and return
        a :class:`~repro.serve.gan_engine.GanFuture` (thread-safe).

        The first call hands the server's program, RNG key, and
        remainder buffer to an internal single-bucket
        :class:`~repro.serve.gan_engine.GanEngine`; the stream picks up
        exactly where the synchronous calls left off, so mixing
        ``generate`` and ``submit`` never forks or reorders it."""
        return self._ensure_engine().submit(n)

    def close(self, drain: bool = True) -> None:
        """Shut the async engine down (no-op if :meth:`submit` was
        never called).  ``drain=True`` answers queued requests first;
        ``drain=False`` fails unscheduled ones with ``ServerClosed``."""
        if self._engine is not None:
            self._engine.close(drain=drain)

    def __enter__(self) -> "GanServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)

    def _ensure_engine(self):
        if self._engine is None:
            from repro.serve.gan_engine import GanEngine
            self._engine = GanEngine(
                self.cfg, self.params, buckets=(self.batch_size,),
                policy=self.policy, program=self.program,
                key=self.key, spare=self._spare, warmup=False)
            self._set_spare(None)   # the engine owns the buffer now
        return self._engine

    def generate(self, n: int) -> np.ndarray:
        """Generate ``n`` images (n, *spatial, C) as numpy.  Remainder
        samples from the final batch are buffered for the next call,
        never discarded.  After the first :meth:`submit`, delegates to
        the async engine (same stream, same accounting)."""
        if int(n) <= 0:
            raise ValueError(f"n must be positive, got {n}")
        if self._engine is not None:
            return self._engine.generate(n)
        t0 = time.perf_counter()
        with _obs.trace("serve.generate", server=self.server_id,
                        n=int(n)) as sp:
            outs = []
            remaining = int(n)
            batches = 0
            if self._spare is not None:
                take = min(len(self._spare), remaining)
                outs.append(self._spare[:take])
                self._set_spare(self._spare[take:])
                self._m_served.inc(take)
                remaining -= take
            while remaining > 0:
                z = jax.random.normal(self._next_key(),
                                      (self.batch_size, self.cfg.z_dim))
                if self._in_sharding is not None:
                    z = jax.device_put(z, self._in_sharding)
                img = np.asarray(self._generate(self.params, z))
                self._m_batches.inc()
                batches += 1
                take = min(self.batch_size, remaining)
                self._m_served.inc(take)
                self._m_occupancy.observe(take / self.batch_size)
                remaining -= take
                outs.append(img[:take])
                if take < self.batch_size:
                    self._set_spare(img[take:])
            out = np.concatenate(outs, axis=0)
            sp.set(batches=batches, buffered=self.samples_buffered)
        self._m_request_us.observe((time.perf_counter() - t0) * 1e6)
        return out

    def describe(self) -> str:
        """The server's frozen execution: the program's per-layer
        records (op, geometry, epilogue, resolved backend/blocks,
        provenance)."""
        return self.program.describe()

    def __repr__(self) -> str:
        return (f"GanServer(model={self.cfg.name!r}, "
                f"batch_size={self.batch_size}, "
                f"policy={self.program.spec.summary()}, "
                f"served={self.samples_served}, "
                f"buffered={self.samples_buffered}, "
                f"discarded={self.samples_discarded})")
