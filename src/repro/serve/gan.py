"""Batched GAN image-generation service on the unified dataflow dispatch.

The serving analogue of `serve.engine.DecodeEngine` for the GAN
workloads: a fixed-batch jitted generator (jit-stable shapes — one trace,
one μop compilation per layer geometry thanks to the ``core.dataflow``
cache).  A ``generate(n)`` call rounds work up to full batches and slices
the tail, so arbitrary request sizes share one compiled executable.
Calls are synchronous and the server is single-threaded: it advances its
own RNG state per batch, so drive it from one thread (or shard requests
across servers with distinct seeds).

The execution path is the server's :class:`~repro.core.dataflow
.DataflowPolicy` (default: the config's own policy; pass
``DataflowPolicy()`` explicitly for platform auto-selection — Pallas on
TPU, polyphase elsewhere)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import DataflowPolicy
from repro.models.gan import GanConfig, generator_apply

__all__ = ["GanServer"]


class GanServer:
    def __init__(self, cfg: GanConfig, g_params, batch_size: int = 8,
                 policy: DataflowPolicy | None = None, seed: int = 0):
        if int(batch_size) <= 0:
            raise ValueError(f"batch_size must be positive, "
                             f"got {batch_size}")
        self.cfg = cfg
        self.params = g_params
        self.batch_size = int(batch_size)
        self.policy = policy or cfg.policy
        self.key = jax.random.PRNGKey(seed)
        self.batches_served = 0

        @jax.jit
        def _generate(params, z):
            return generator_apply(params, z, cfg, policy=self.policy)
        self._generate = _generate

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def generate(self, n: int) -> np.ndarray:
        """Generate ``n`` images (n, *spatial, C) as numpy."""
        if int(n) <= 0:
            raise ValueError(f"n must be positive, got {n}")
        outs = []
        remaining = int(n)
        while remaining > 0:
            z = jax.random.normal(self._next_key(),
                                  (self.batch_size, self.cfg.z_dim))
            img = self._generate(self.params, z)
            self.batches_served += 1
            outs.append(np.asarray(img[:remaining]))
            remaining -= self.batch_size
        return np.concatenate(outs, axis=0)
