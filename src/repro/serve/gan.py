"""Batched GAN image-generation service on the unified dataflow dispatch.

The serving analogue of `serve.engine.DecodeEngine` for the GAN
workloads: a fixed-batch jitted generator (jit-stable shapes — one trace,
one μop compilation per layer geometry thanks to the ``core.dataflow``
cache).  A ``generate(n)`` call rounds work up to full batches and slices
the tail; ``samples_served`` / ``samples_discarded`` account for every
sample the generator produced (discarded tail samples are real compute —
they must be visible to capacity planning, not silently dropped).
Calls are synchronous and the server is single-threaded: it advances its
own RNG state per batch, so drive it from one thread (or shard requests
across servers with distinct seeds).

The execution path is the server's :class:`~repro.core.dataflow
.DataflowPolicy` (default: the config's own policy; pass
``DataflowPolicy()`` explicitly for platform auto-selection).  With
``backend="auto"`` the server **warms the autotuning planner on
construction**: every generator-layer geometry — keyed on the fused
bias+activation epilogue the model actually dispatches — gets a
measured plan before the first jit trace, so the traced executable runs
the tuned backends/block shapes (zero measurements when the planner's
plan file is already warm).  The resolved per-layer plans are exposed
in ``repr``.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.dataflow import DataflowPolicy
from repro.models.gan import GanConfig, generator_apply

__all__ = ["GanServer"]


class GanServer:
    def __init__(self, cfg: GanConfig, g_params, batch_size: int = 8,
                 policy: DataflowPolicy | None = None, seed: int = 0,
                 warm_plans: bool = True):
        if int(batch_size) <= 0:
            raise ValueError(f"batch_size must be positive, "
                             f"got {batch_size}")
        self.cfg = cfg
        self.params = g_params
        self.batch_size = int(batch_size)
        self.policy = policy or cfg.policy
        self.key = jax.random.PRNGKey(seed)
        self.batches_served = 0
        self.samples_served = 0
        self.samples_discarded = 0
        self.plans: dict[str, object] = {}
        if self.policy.backend == "auto" and warm_plans:
            from repro.tune import get_planner, warm_gan_plans
            self.plans = warm_gan_plans(cfg, self.batch_size,
                                        get_planner(),
                                        generator_only=True)

        @jax.jit
        def _generate(params, z):
            return generator_apply(params, z, cfg, policy=self.policy)
        self._generate = _generate

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def generate(self, n: int) -> np.ndarray:
        """Generate ``n`` images (n, *spatial, C) as numpy."""
        if int(n) <= 0:
            raise ValueError(f"n must be positive, got {n}")
        outs = []
        remaining = int(n)
        while remaining > 0:
            z = jax.random.normal(self._next_key(),
                                  (self.batch_size, self.cfg.z_dim))
            img = self._generate(self.params, z)
            self.batches_served += 1
            take = min(self.batch_size, remaining)
            self.samples_served += take
            self.samples_discarded += self.batch_size - take
            outs.append(np.asarray(img[:take]))
            remaining -= self.batch_size
        return np.concatenate(outs, axis=0)

    def resolved_policy(self) -> str:
        """Human-readable resolution of this server's policy: the pinned
        or heuristic backend name, or — for ``backend="auto"`` — the
        per-layer tuned plans from the construction warmup."""
        if self.policy.backend != "auto":
            g_layers, _ = self.cfg.layers
            return self.policy.resolve(len(g_layers[0].in_spatial))
        if not self.plans:
            return "auto(unplanned→heuristic)"
        per_layer = ", ".join(
            f"{name.split('/', 1)[1]}→{plan.backend}"
            + (f"[{'x'.join(map(str, plan.blocks))}]" if plan.blocks
               else "")
            for name, plan in self.plans.items())
        return f"auto({per_layer})"

    def __repr__(self) -> str:
        return (f"GanServer(model={self.cfg.name!r}, "
                f"batch_size={self.batch_size}, "
                f"policy={self.resolved_policy()}, "
                f"served={self.samples_served}, "
                f"discarded={self.samples_discarded})")
