"""Continuous-batching async GAN serving engine.

:class:`GanEngine` is the GAN analogue of :class:`~repro.serve.engine
.DecodeEngine`: a thread-safe front-end that turns many concurrent
sample requests into a small number of well-packed device batches.

* **Request queue + scheduler thread.**  ``submit(n)`` is callable from
  any number of producer threads; it enqueues a :class:`GanFuture` and
  returns immediately.  A single scheduler thread owns the device work:
  it drains the queue, coalesces pending demand, advances the RNG
  stream, dispatches compute, and distributes results — so every
  JAX-visible mutation stays single-threaded while the front door is
  concurrent.
* **Ahead-of-time bucket set.**  At construction the engine builds one
  :class:`~repro.program.ProgramSpec` (the config → policy → plan walk
  runs once) and fans it out into one :class:`~repro.program.Program`
  per batch-size bucket (:func:`repro.program.build_bucket_programs`).
  Each coalesced batch runs the smallest bucket that covers pending
  demand (the largest bucket under overload), so serving never traces
  per request: ``programs[b].traces`` stays at 1 per bucket.
* **Transfer/compute overlap.**  Dispatch is asynchronous: the
  scheduler launches batch *k+1* before it blocks on batch *k*'s
  device→host transfer, so the copy of one batch rides under the
  compute of the next (``pipeline_depth`` batches stay in flight).
* **Nothing is discarded.**  Tail samples of a bucket beyond what the
  coalesced requests asked for land in the same remainder buffer the
  synchronous :class:`~repro.serve.gan.GanServer` keeps, and serve the
  next requests first.  The accounting invariant becomes
  ``served + buffered + discarded == generated + initial spare``;
  ``samples_discarded`` stays 0 except when ``close(drain=False)``
  cancels requests whose samples were already in flight.
* **Clean shutdown.**  ``close()`` (or exiting the context manager)
  drains: queued requests are answered, then the scheduler exits.
  ``close(drain=False)`` answers what is already in flight and fails
  the rest with :class:`ServerClosed`.  A scheduler-side exception
  fails every outstanding request with that exception.  In every case
  a ``GanFuture.result()`` returns or raises — it never hangs.

**Determinism.**  The sample stream is defined by ``(seed, the
sequence of batch sizes drawn)``: one key split per batch, exactly like
the synchronous server.  With a single bucket equal to a
``GanServer``'s ``batch_size``, the engine's stream is bit-identical to
``GanServer.generate`` at equal seeds, whatever the request
interleaving — requests are filled FIFO in stream order, and each
future's ``offset`` records its slice's stream position so concurrent
consumers can reassemble the sequential stream (pinned by tests).
With multiple buckets the bucket *choice* depends on instantaneous
queue depth, so the stream is reproducible only for a deterministic
submission schedule.

Metrics (labels ``engine=<id>``): ``engine.requests`` /
``engine.batches`` / ``engine.samples_served`` / ``.samples_discarded``
counters, ``engine.queue_depth`` / ``engine.samples_buffered`` gauges,
``engine.batch_occupancy`` / ``engine.request_us`` histograms (p50/p99
per-request end-to-end latency), plus an ``engine.request`` span per
completed request (via :func:`repro.obs.emit_span` — submit and
completion happen on different threads).  See ``docs/serving.md``.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque

import jax
import numpy as np

from repro import obs as _obs
from repro.core.dataflow import DataflowPolicy
from repro.models.gan import GanConfig
from repro.program import Program, ProgramSpec, build_bucket_programs
from repro.program.spec import _UNSET as _MESH_UNSET

__all__ = ["GanEngine", "GanFuture", "ServerClosed", "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS = (1, 2, 4, 8)

# Occupancy is assigned/bucket in (0, 1] — latency buckets make no
# sense for it (same bounds the synchronous server uses).
_OCCUPANCY_BOUNDS = tuple(i / 10 for i in range(1, 11))

_ENGINE_SEQ = itertools.count()


class ServerClosed(RuntimeError):
    """The engine was closed before (or while) this request could be
    served; also raised by ``submit`` after ``close``."""


class GanFuture:
    """Handle for one submitted request: blocks in :meth:`result` until
    the engine answers (samples or an error) — never hangs past
    engine shutdown."""

    __slots__ = ("n", "offset", "_chunks", "_filled", "_result",
                 "_error", "_event", "_t0", "_t1", "_t0_us")

    def __init__(self, n: int):
        self.n = int(n)
        #: stream position of this request's first sample (set when the
        #: scheduler allocates it; allocation is FIFO, so sorting
        #: completed futures by offset reassembles the sequential
        #: stream).  None until allocated.
        self.offset: int | None = None
        self._chunks: list[np.ndarray] = []
        self._filled = 0
        self._result: np.ndarray | None = None
        self._error: BaseException | None = None
        self._event = threading.Event()
        self._t0 = time.perf_counter()
        self._t0_us = _obs.now_us()
        self._t1: float | None = None

    # -- engine side (scheduler thread, engine lock held) -------------------
    def _deliver(self, chunk: np.ndarray) -> None:
        self._chunks.append(chunk)
        self._filled += len(chunk)
        if self._filled >= self.n:
            self._result = self._chunks[0] if len(self._chunks) == 1 \
                else np.concatenate(self._chunks, axis=0)
            self._chunks = []
            self._finish()

    def _fail(self, err: BaseException) -> None:
        if not self._event.is_set():
            self._error = err
            self._finish()

    def _finish(self) -> None:
        self._t1 = time.perf_counter()
        self._event.set()

    # -- caller side --------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def exception(self, timeout: float | None = None
                  ) -> BaseException | None:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request for {self.n} samples not "
                               f"answered within {timeout}s")
        return self._error

    def result(self, timeout: float | None = None) -> np.ndarray:
        err = self.exception(timeout)
        if err is not None:
            raise err
        return self._result

    @property
    def latency_us(self) -> float | None:
        """Submit→answer wall-clock (None while pending)."""
        if self._t1 is None:
            return None
        return (self._t1 - self._t0) * 1e6


class _Batch:
    """One dispatched bucket: the in-flight device array plus the FIFO
    share list saying which request gets which rows at resolution."""

    __slots__ = ("size", "shares", "assigned", "dev")

    def __init__(self, size: int):
        self.size = size
        self.shares: list[tuple[GanFuture, int]] = []
        self.assigned = 0
        self.dev = None


class GanEngine:
    """Continuous-batching asynchronous server for one GAN generator.

    Parameters mirror :class:`~repro.serve.gan.GanServer` where they
    overlap; the serving-specific ones:

    ``buckets``
        The ahead-of-time compiled batch sizes.  Each scheduled batch
        uses the smallest bucket covering coalesced pending demand
        (the largest bucket when demand exceeds it).
    ``program``
        An exported/tuned generator :class:`~repro.program.Program` to
        serve; its frozen spec seeds every bucket executable.  Built
        from ``cfg`` when omitted (``measure=warm_plans`` for ``auto``
        policies, exactly like the synchronous server).
    ``pipeline_depth``
        How many dispatched batches may be unresolved at once (≥1).
        Depth 1 already overlaps batch *k*'s device→host transfer with
        batch *k+1*'s compute.
    ``max_pending``
        Backpressure: ``submit`` blocks while this many requests are
        queued unallocated (None = unbounded).
    ``warmup``
        Trace every bucket executable at construction (a dummy forward
        per bucket) so no request ever pays compile time.
    ``key`` / ``spare``
        Advanced (used by the ``GanServer`` façade): start the RNG
        stream from an existing key instead of ``seed``, and seed the
        remainder buffer with already-generated samples.
    ``dtype``
        Storage-precision override ("float32"/"bfloat16"/"float16",
        aliases accepted): replaces ``cfg.dtype`` before the program
        build.  When serving an exported ``program=`` without an
        explicit override, the engine adopts the program's precision.
        Pass ``g_params=None`` with a quantized (int8-exported)
        program to serve its embedded weights.
    """

    def __init__(self, cfg: GanConfig, g_params,
                 buckets=DEFAULT_BUCKETS, *,
                 policy: DataflowPolicy | None = None, seed: int = 0,
                 warm_plans: bool = True, program: Program | None = None,
                 pipeline_depth: int = 1, max_pending: int | None = None,
                 warmup: bool = True, key=None,
                 spare: np.ndarray | None = None, mesh=_MESH_UNSET,
                 dtype: str | None = None):
        if dtype is not None:
            # serving-time storage-precision override (canonicalized by
            # GanConfig; accumulation stays f32 — see repro.quant)
            cfg = dataclasses.replace(cfg, dtype=dtype)
        if g_params is None:
            # int8-deploy flow: a quantized program carries its own
            # (dequantized-at-load) parameters
            if program is None or not program.quantized:
                raise ValueError("g_params=None needs a quantized "
                                 "program= (int8 export) to serve")
            g_params = program.params
        self.cfg = cfg
        self.params = g_params
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        if not self.buckets or self.buckets[0] <= 0:
            raise ValueError(f"buckets must be positive ints, got "
                             f"{tuple(buckets)}")
        if int(pipeline_depth) < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got "
                             f"{pipeline_depth}")
        if max_pending is not None and int(max_pending) < 1:
            raise ValueError(f"max_pending must be >= 1 or None, got "
                             f"{max_pending}")
        self.policy = policy or cfg.policy
        self.pipeline_depth = int(pipeline_depth)
        self.max_pending = None if max_pending is None \
            else int(max_pending)
        self.key = key if key is not None else jax.random.PRNGKey(seed)

        if program is not None:
            if program.spec.role != "generator":
                raise ValueError(f"GanEngine needs a generator program, "
                                 f"got role={program.spec.role!r}")
            if dtype is None and program.spec.dtype != cfg.dtype:
                # adopt the exported program's storage precision unless
                # the caller pinned one explicitly
                cfg = dataclasses.replace(cfg, dtype=program.spec.dtype)
                self.cfg = cfg
            expected = ProgramSpec.build(cfg, self.buckets[-1],
                                         "generator",
                                         policy=DataflowPolicy())
            if program.spec.geometry_signature() != \
                    expected.geometry_signature():
                raise ValueError(
                    f"program {program.spec.model!r} froze a different "
                    f"workload than config {cfg.name!r} builds "
                    f"(topology / z_dim / channel-scale / epilogue / "
                    f"precision drift)")
            spec = program.spec
        else:
            spec = ProgramSpec.build(cfg, self.buckets[-1], "generator",
                                     policy=self.policy,
                                     measure=warm_plans, mesh=mesh)
        self.spec = spec
        self.programs = build_bucket_programs(spec, self.buckets)
        # all bucket programs share the spec (and the local device
        # count), so one probe answers for the whole set: the batch
        # placement to device_put with (None when unsharded — including
        # the degraded-mesh case) and the span-attr mesh identity
        probe = self.programs[self.buckets[0]]
        self._in_sharding = probe.input_sharding
        self._devices = probe.device_count
        self._mesh_str = probe.mesh_str
        if probe.mesh is not None:
            bad = [b for b in self.buckets if b % spec.mesh[0]]
            if bad:
                raise ValueError(
                    f"buckets {bad} do not divide over the program's "
                    f"data axis of {spec.mesh[0]} (mesh "
                    f"{probe.mesh_str})")

        self.engine_id = f"{cfg.name}#{next(_ENGINE_SEQ)}"
        labels = {"engine": self.engine_id}
        self._m_requests = _obs.counter("engine.requests", **labels)
        self._m_batches = _obs.counter("engine.batches", **labels)
        self._m_generated = _obs.counter("engine.samples_generated",
                                         **labels)
        self._m_served = _obs.counter("engine.samples_served", **labels)
        self._m_discarded = _obs.counter("engine.samples_discarded",
                                         **labels)
        self._m_queue = _obs.gauge("engine.queue_depth", **labels)
        self._m_buffered = _obs.gauge("engine.samples_buffered", **labels)
        self._m_request_us = _obs.histogram("engine.request_us", **labels)
        self._m_occupancy = _obs.histogram(
            "engine.batch_occupancy", bounds=_OCCUPANCY_BOUNDS, **labels)

        # Shared state (producers ↔ scheduler): the queue, closed flag,
        # and futures' delivery all mutate under this lock.  The RNG
        # key, dispatch deque, and spare buffer are scheduler-thread
        # only.
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: deque[GanFuture] = deque()
        self._closed = False
        self._drain = True
        self._alloc_pos = 0
        self._dispatched: deque[_Batch] = deque()
        self._spare: np.ndarray | None = None
        self.initial_spare = 0
        if spare is not None and len(spare):
            self._spare = np.asarray(spare)
            self.initial_spare = len(self._spare)
            self._m_buffered.set(self.initial_spare)

        if warmup:
            z0 = np.zeros((1, cfg.z_dim), np.float32)
            for b, prog in self.programs.items():
                z = np.broadcast_to(z0, (b, cfg.z_dim))
                if self._in_sharding is not None:
                    z = jax.device_put(z, self._in_sharding)
                jax.block_until_ready(prog.apply(g_params, z))

        self._thread = threading.Thread(
            target=self._run, name=f"gan-engine-{self.engine_id}",
            daemon=True)
        self._thread.start()

    # -- producer API -------------------------------------------------------
    def submit(self, n: int, timeout: float | None = None) -> GanFuture:
        """Enqueue a request for ``n`` samples (thread-safe, returns
        immediately once admitted).  Blocks while ``max_pending``
        requests are already waiting; raises :class:`ServerClosed` once
        the engine is closed."""
        if int(n) <= 0:
            raise ValueError(f"n must be positive, got {n}")
        fut = GanFuture(n)
        with self._cv:
            while (not self._closed and self.max_pending is not None
                   and len(self._queue) >= self.max_pending):
                if not self._cv.wait(timeout):
                    raise TimeoutError(
                        f"queue full ({self.max_pending} pending) for "
                        f"{timeout}s")
            if self._closed:
                raise ServerClosed(f"engine {self.engine_id} is closed")
            self._queue.append(fut)
            self._m_requests.inc()
            self._m_queue.set(len(self._queue))
            self._cv.notify_all()
        return fut

    def generate(self, n: int, timeout: float | None = None
                 ) -> np.ndarray:
        """Synchronous convenience: ``submit(n).result()``."""
        return self.submit(n).result(timeout)

    def close(self, drain: bool = True,
              timeout: float | None = None) -> None:
        """Stop the engine.  ``drain=True`` (default) answers every
        queued request first; ``drain=False`` answers only requests
        whose samples are already dispatched and fails the rest with
        :class:`ServerClosed`.  Idempotent; safe from any thread."""
        with self._cv:
            if not self._closed:
                self._closed = True
                self._drain = bool(drain)
            self._cv.notify_all()
        if self._thread is not threading.current_thread():
            self._thread.join(timeout)

    def __enter__(self) -> "GanEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # an exception escaping the block must not hang on a full drain
        self.close(drain=exc_type is None)

    # -- accounting ---------------------------------------------------------
    @property
    def batches_served(self) -> int:
        return self._m_batches.value

    @property
    def samples_generated(self) -> int:
        return self._m_generated.value

    @property
    def samples_served(self) -> int:
        return self._m_served.value

    @property
    def samples_discarded(self) -> int:
        return self._m_discarded.value

    @property
    def samples_buffered(self) -> int:
        return 0 if self._spare is None else len(self._spare)

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    def describe(self) -> str:
        return self.spec.describe()

    def __repr__(self) -> str:
        return (f"GanEngine(model={self.cfg.name!r}, "
                f"buckets={self.buckets}, "
                f"policy={self.spec.summary()}, "
                f"served={self.samples_served}, "
                f"buffered={self.samples_buffered}, "
                f"discarded={self.samples_discarded}, "
                f"closed={self._closed})")

    # -- scheduler (single thread) ------------------------------------------
    def _run(self) -> None:
        try:
            self._loop()
        except BaseException as e:   # noqa: BLE001 — must answer futures
            self._fail_outstanding(e)
        finally:
            with self._cv:
                self._closed = True
                self._cv.notify_all()

    def _loop(self) -> None:
        while True:
            action = self._next_action()
            if action == "stop":
                break
            if isinstance(action, _Batch):
                self._dispatch(action)
                # overlap: block on the oldest transfer only once a
                # newer batch's compute is already in flight
                while len(self._dispatched) > self.pipeline_depth:
                    self._resolve(self._dispatched.popleft())
            else:   # "flush": no new demand — settle what's in flight
                while self._dispatched:
                    self._resolve(self._dispatched.popleft())
        # shutdown (non-drain close): requests that would need further
        # compute fail now — so their shares in still-unresolved
        # batches count as discarded — then in-flight batches settle,
        # answering every fully-dispatched request.
        with self._cv:
            for fut in list(self._queue):
                if fut.n - fut._filled - self._promised(fut) > 0:
                    fut._fail(ServerClosed(
                        f"engine {self.engine_id} closed before this "
                        f"request was scheduled"))
                    self._queue.remove(fut)
            self._m_queue.set(len(self._queue))
        while self._dispatched:
            self._resolve(self._dispatched.popleft())

    def _next_action(self):
        """Wait for work; serve the spare buffer; return the next batch
        to dispatch, ``"flush"`` to settle in-flight transfers, or
        ``"stop"``."""
        with self._cv:
            while True:
                self._serve_spare_locked()
                demand = self._fill_inflight_locked()
                if demand > 0:
                    if self._closed and not self._drain:
                        return "stop"
                    return self._make_batch_locked(demand)
                if self._dispatched:
                    return "flush"
                if self._closed:
                    return "stop"
                self._cv.wait()

    def _demand_locked(self) -> int:
        return sum(f.n - f._filled - self._promised(f)
                   for f in self._queue)

    def _promised(self, fut: GanFuture) -> int:
        # samples already assigned to `fut` in unresolved batches
        return sum(c for b in self._dispatched
                   for f, c in b.shares if f is fut)

    def _serve_spare_locked(self) -> None:
        """Drain the remainder buffer into the head of the queue (no
        compute; completes small requests instantly)."""
        while self._spare is not None and len(self._spare) and \
                self._queue:
            fut = self._queue[0]
            need = fut.n - fut._filled - self._promised(fut)
            if need <= 0:
                break
            take = min(need, len(self._spare))
            self._allocate_locked(fut, take)
            self._deliver_locked(fut, self._spare[:take])
            self._spare = self._spare[take:]
            if not len(self._spare):
                self._spare = None
        self._m_buffered.set(self.samples_buffered)

    def _fill_inflight_locked(self) -> int:
        """Assign unclaimed tail capacity of dispatched batches to
        queued demand; returns the demand still uncovered."""
        for b in self._dispatched:
            for fut in list(self._queue):
                free = b.size - b.assigned
                if free <= 0:
                    break
                need = fut.n - fut._filled - self._promised(fut)
                if need <= 0:
                    continue
                take = min(free, need)
                self._allocate_locked(fut, take)
                b.shares.append((fut, take))
                b.assigned += take
        return self._demand_locked()

    def _make_batch_locked(self, demand: int) -> _Batch:
        """Coalesce queued demand into the smallest covering bucket
        (largest under overload) and pre-assign its rows FIFO."""
        size = next((b for b in self.buckets if b >= demand),
                    self.buckets[-1])
        batch = _Batch(size)
        for fut in list(self._queue):
            free = size - batch.assigned
            if free <= 0:
                break
            need = fut.n - fut._filled - self._promised(fut)
            if need <= 0:
                continue
            take = min(free, need)
            self._allocate_locked(fut, take)
            batch.shares.append((fut, take))
            batch.assigned += take
        return batch

    def _allocate_locked(self, fut: GanFuture, take: int) -> None:
        if fut.offset is None:
            fut.offset = self._alloc_pos
        self._alloc_pos += take

    def _deliver_locked(self, fut: GanFuture, chunk: np.ndarray) -> None:
        fut._deliver(chunk)
        self._m_served.inc(len(chunk))
        if fut.done():
            if self._queue and self._queue[0] is fut:
                self._queue.popleft()
            else:                       # filled out of head position
                self._queue.remove(fut)
            self._m_queue.set(len(self._queue))
            if fut.latency_us is not None:
                self._m_request_us.observe(fut.latency_us)
            _obs.emit_span("engine.request", fut._t0_us,
                           engine=self.engine_id, n=fut.n,
                           offset=fut.offset, devices=self._devices,
                           mesh=self._mesh_str)
            self._cv.notify_all()       # backpressure: queue slot freed

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _dispatch(self, batch: _Batch) -> None:
        z = jax.random.normal(self._next_key(),
                              (batch.size, self.cfg.z_dim))
        if self._in_sharding is not None:
            z = jax.device_put(z, self._in_sharding)
        # async dispatch: returns a device future, does not block
        batch.dev = self.programs[batch.size].apply(self.params, z)
        self._m_generated.inc(batch.size)
        self._dispatched.append(batch)

    def _resolve(self, batch: _Batch) -> None:
        """Block on the batch's device→host transfer, then distribute
        rows to its shares in FIFO stream order; the unclaimed tail
        joins the remainder buffer."""
        out = np.asarray(batch.dev)
        batch.dev = None
        self._m_batches.inc()
        self._m_occupancy.observe(batch.assigned / batch.size)
        with self._cv:
            pos = 0
            for fut, count in batch.shares:
                chunk = out[pos:pos + count]
                pos += count
                if fut._event.is_set():   # cancelled mid-flight
                    self._m_discarded.inc(count)
                    continue
                self._deliver_locked(fut, chunk)
            if pos < batch.size:
                tail = out[pos:]
                self._spare = tail if self._spare is None \
                    else np.concatenate([self._spare, tail], axis=0)
                self._m_buffered.set(len(self._spare))

    def _fail_outstanding(self, err: BaseException) -> None:
        with self._cv:
            self._closed = True
            # nothing from an unresolved batch was delivered, so the
            # whole batch (shares and tail alike) is lost compute
            self._m_discarded.inc(sum(b.size for b in self._dispatched))
            self._dispatched.clear()
            for fut in self._queue:
                fut._fail(err)
            self._queue.clear()
            self._m_queue.set(0)
            self._cv.notify_all()
