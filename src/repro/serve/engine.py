"""Batched decode engine with slot-based continuous batching.

The engine maintains a fixed pool of ``n_slots`` sequence slots sharing one
static-shaped cache (jit-stable).  Requests are admitted into free slots
(prefill writes the prompt's cache entries at the slot's rows), every
``step()`` decodes *all* active slots in one batched forward, and finished
sequences (EOS or max-length) free their slots immediately — new requests
can be admitted between any two steps (continuous batching at step
granularity).

The decode step is jitted with the cache **donated**, so the cache is
updated in place on device (no per-step reallocation).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as tr
from repro.serve.sampling import sample

__all__ = ["EngineConfig", "DecodeEngine", "Request"]


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 512
    max_new: int = 0           # 0 → generate until max_len
    eos_id: int = -1           # -1 → never stop on token
    temperature: float = 0.0   # greedy by default
    top_k: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig,
                 flags: tr.RunFlags = tr.RunFlags(), seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.flags = flags
        self.cache = tr.init_cache(cfg, ecfg.n_slots, ecfg.max_len)
        self.lengths = jnp.full((ecfg.n_slots,), 0, jnp.int32)
        self.active = np.zeros((ecfg.n_slots,), bool)
        self.slot_req: list[Request | None] = [None] * ecfg.n_slots
        self.last_tokens = jnp.zeros((ecfg.n_slots, 1), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self.steps = 0

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _decode(params, cache, tokens, lengths, key):
            logits, cache = tr.decode_step(params, cache, tokens, lengths,
                                           cfg, flags)
            toks = sample(logits, key, temperature=ecfg.temperature,
                          top_k=ecfg.top_k)
            return toks, cache
        self._decode = _decode

        @jax.jit
        def _prefill_one(params, tokens):
            # tokens (1, S) → (next_token_logits, cache_for_prompt)
            logits, cache, _ = tr.forward(params, {"tokens": tokens}, cfg,
                                          mode="prefill", flags=flags)
            return logits[:, -1], cache
        self._prefill_one = _prefill_one

    # -- slot management ------------------------------------------------------
    def try_admit(self, req: Request) -> bool:
        free = [i for i in range(self.ecfg.n_slots) if not self.active[i]]
        if not free:
            return False
        slot = free[0]
        s = len(req.prompt)
        assert s < self.ecfg.max_len, "prompt too long for engine"
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, pcache = self._prefill_one(self.params, toks)
        # write the prompt cache into the slot's rows
        self.cache = _merge_slot_cache(self.cache, pcache, slot, s)
        first = sample(logits, self._next_key(),
                       temperature=self.ecfg.temperature,
                       top_k=self.ecfg.top_k)
        req.generated.append(int(first[0]))
        self.last_tokens = self.last_tokens.at[slot, 0].set(first[0])
        self.lengths = self.lengths.at[slot].set(s)
        self.active[slot] = True
        self.slot_req[slot] = req
        return True

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # -- stepping -------------------------------------------------------------
    def step(self):
        """One batched decode step over all active slots."""
        if not self.active.any():
            return
        toks, self.cache = self._decode(self.params, self.cache,
                                        self.last_tokens, self.lengths,
                                        self._next_key())
        self.steps += 1
        self.lengths = self.lengths + jnp.asarray(self.active, jnp.int32)
        toks_np = np.asarray(toks)
        self.last_tokens = toks[:, None]
        for slot in range(self.ecfg.n_slots):
            if not self.active[slot]:
                continue
            req = self.slot_req[slot]
            tok = int(toks_np[slot])
            req.generated.append(tok)
            if tok == self.ecfg.eos_id or \
                    (self.ecfg.max_new and
                     len(req.generated) >= self.ecfg.max_new) or \
                    int(self.lengths[slot]) >= self.ecfg.max_len - 1:
                req.done = True
                self.active[slot] = False
                self.slot_req[slot] = None

    def run(self, requests: list[Request], max_steps: int = 10_000):
        """Admit+step until all requests complete (continuous batching)."""
        pending = list(requests)
        done: list[Request] = []
        while (pending or self.active.any()) and self.steps < max_steps:
            while pending and self.try_admit(pending[0]):
                pending.pop(0)
            self.step()
            done.extend(r for r in requests
                        if r.done and r not in done)
        return requests


def _merge_slot_cache(cache, pcache, slot: int, s: int):
    """Write a (1, S, ...) prefill cache into row `slot` of the engine
    cache (length dims differ: prefill cache covers the prompt only)."""
    def merge(c, p):
        # c: (L, n_slots, T, ...) or (L, n_slots, ...) state caches
        if p.ndim >= 3 and c.shape[2] >= p.shape[2] and c.ndim == p.ndim \
                and p.shape[1] == 1:
            # sequence cache: write first s rows
            idx = (slice(None), slice(slot, slot + 1), slice(0, p.shape[2]))
            return c.at[idx].set(p)
        if p.shape[1] == 1:  # state cache (ssm h / conv)
            return c.at[:, slot:slot + 1].set(p)
        raise ValueError((c.shape, p.shape))
    return jax.tree.map(merge, cache, pcache)
