"""OLMoE-1B-7B — MoE transformer (64 experts, top-8).

16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304
[arXiv:2409.02060].
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    moe=True,
    n_experts=64,
    top_k=8,
    expert_d_ff=1024,
    mlp_kind="swiglu",
    rope_theta=1e4,
))
