"""The six GAN workloads of the paper (Table I), as layer topologies.

Layer geometries follow the source papers (DCGAN-family generators:
stride-2 4×4 transposed convs halving channels while doubling spatial size;
3D-GAN: volumetric 4×4×4 stride-2; MAGAN: an autoencoder discriminator and a
generator mixing stride-1 and stride-2 transposed convs, which is why its
inserted-zero fraction — and hence its GANAX speedup — is the lowest, Fig. 1
/ Fig. 8).  Where a source paper leaves a dimension unspecified we follow
the DCGAN convention and note it here rather than in the code.

These topologies drive: the analytical reproduction (benchmarks/fig*),
the executable GAN models (models/gan.py), and the GAN training examples.
"""

from __future__ import annotations

from repro.core.analytical import ConvLayer

__all__ = ["GAN_MODELS", "gan_layers"]


def _t(name, hw, k, s, p, cin, cout, dims=2):
    return ConvLayer(name=name, in_spatial=(hw,) * dims, kernel=(k,) * dims,
                     strides=(s,) * dims, paddings=(p,) * dims,
                     cin=cin, cout=cout, transposed=True)


def _c(name, hw, k, s, p, cin, cout, dims=2):
    # Plain (downsampling) conv: stride s on its input resolution.
    return ConvLayer(name=name, in_spatial=(hw,) * dims, kernel=(k,) * dims,
                     strides=(s,) * dims, paddings=(p,) * dims,
                     cin=cin, cout=cout, transposed=False)


# --------------------------------------------------------------------------
# DCGAN (Radford et al. 2015): 64×64 generator, 4 tconv / 5 conv.
# --------------------------------------------------------------------------
DCGAN_G = [
    _t("g1", 4, 4, 2, 1, 1024, 512),
    _t("g2", 8, 4, 2, 1, 512, 256),
    _t("g3", 16, 4, 2, 1, 256, 128),
    _t("g4", 32, 4, 2, 1, 128, 3),
]
DCGAN_D = [
    _c("d1", 64, 4, 2, 1, 3, 128),
    _c("d2", 32, 4, 2, 1, 128, 256),
    _c("d3", 16, 4, 2, 1, 256, 512),
    _c("d4", 8, 4, 2, 1, 512, 1024),
    _c("d5", 4, 4, 1, 0, 1024, 1),
]

# --------------------------------------------------------------------------
# 3D-GAN (Wu et al. 2016): 64³ voxel generator, 4 tconv3d / 5 conv3d.
# Stride-2 in 3-D → 87.5% inserted zeros, the paper's highest (Fig. 1).
# --------------------------------------------------------------------------
GAN3D_G = [
    _t("g1", 4, 4, 2, 1, 512, 256, dims=3),
    _t("g2", 8, 4, 2, 1, 256, 128, dims=3),
    _t("g3", 16, 4, 2, 1, 128, 64, dims=3),
    _t("g4", 32, 4, 2, 1, 64, 1, dims=3),
]
GAN3D_D = [
    _c("d1", 64, 4, 2, 1, 1, 64, dims=3),
    _c("d2", 32, 4, 2, 1, 64, 128, dims=3),
    _c("d3", 16, 4, 2, 1, 128, 256, dims=3),
    _c("d4", 8, 4, 2, 1, 256, 512, dims=3),
    _c("d5", 4, 4, 1, 0, 512, 1, dims=3),
]

# --------------------------------------------------------------------------
# ArtGAN (Tan et al. 2017): 5 tconv (4 upsampling + 1 stride-1 refinement).
# --------------------------------------------------------------------------
ARTGAN_G = [
    _t("g1", 4, 4, 2, 1, 1024, 512),
    _t("g2", 8, 4, 2, 1, 512, 256),
    _t("g3", 16, 4, 2, 1, 256, 128),
    _t("g4", 32, 4, 2, 1, 128, 64),
    _t("g5", 64, 5, 1, 2, 64, 3),
]
ARTGAN_D = [
    _c("d1", 64, 4, 2, 1, 3, 64),
    _c("d2", 32, 4, 2, 1, 64, 128),
    _c("d3", 16, 4, 2, 1, 128, 256),
    _c("d4", 8, 4, 2, 1, 256, 512),
    _c("d5", 4, 4, 2, 1, 512, 1024),
    _c("d6", 2, 2, 1, 0, 1024, 1),
]

# --------------------------------------------------------------------------
# DiscoGAN (Kim et al. 2017): encoder-decoder generator (5 conv + 5 tconv).
# --------------------------------------------------------------------------
DISCOGAN_G = [
    _c("e1", 64, 4, 2, 1, 3, 64),
    _c("e2", 32, 4, 2, 1, 64, 128),
    _c("e3", 16, 4, 2, 1, 128, 256),
    _c("e4", 8, 4, 2, 1, 256, 512),
    _c("e5", 4, 4, 2, 1, 512, 1024),
    _t("g1", 2, 4, 2, 1, 1024, 512),
    _t("g2", 4, 4, 2, 1, 512, 256),
    _t("g3", 8, 4, 2, 1, 256, 128),
    _t("g4", 16, 4, 2, 1, 128, 64),
    _t("g5", 32, 4, 2, 1, 64, 3),
]
DISCOGAN_D = [
    _c("d1", 64, 4, 2, 1, 3, 64),
    _c("d2", 32, 4, 2, 1, 64, 128),
    _c("d3", 16, 4, 2, 1, 128, 256),
    _c("d4", 8, 4, 2, 1, 256, 512),
    _c("d5", 4, 4, 1, 0, 512, 1),
]

# --------------------------------------------------------------------------
# GP-GAN (Wu et al. 2017): blending GAN, DCGAN-like decoder with wider
# channels (encoder-decoder; we model the generative tconv stack).
# --------------------------------------------------------------------------
GPGAN_G = [
    _t("g1", 4, 4, 2, 1, 2048, 1024),
    _t("g2", 8, 4, 2, 1, 1024, 512),
    _t("g3", 16, 4, 2, 1, 512, 256),
    _t("g4", 32, 4, 2, 1, 256, 3),
]
GPGAN_D = [
    _c("d1", 64, 4, 2, 1, 3, 64),
    _c("d2", 32, 4, 2, 1, 64, 128),
    _c("d3", 16, 4, 2, 1, 128, 256),
    _c("d4", 8, 4, 2, 1, 256, 512),
    _c("d5", 4, 4, 1, 0, 512, 1),
]

# --------------------------------------------------------------------------
# MAGAN (Wang et al. 2017): 6 tconv generator; the refinement layers are
# stride-1 (no inserted zeros), so the MAC-weighted zero fraction is the
# pool's lowest → smallest speedup (paper: 1.3×).  The discriminator is an
# autoencoder (6 conv + 6 tconv); per the paper's methodology only its conv
# layers count toward the discriminator totals.
# --------------------------------------------------------------------------
MAGAN_G = [
    _t("g1", 4, 4, 2, 1, 512, 256),
    _t("g2", 8, 5, 1, 2, 256, 256),
    _t("g3", 8, 4, 2, 1, 256, 128),
    _t("g4", 16, 5, 1, 2, 128, 128),
    _t("g5", 16, 5, 1, 2, 128, 64),
    _t("g6", 16, 5, 1, 2, 64, 3),
]
MAGAN_D = [
    _c("d1", 16, 4, 2, 1, 3, 64),
    _c("d2", 8, 4, 2, 1, 64, 128),
    _c("d3", 4, 4, 2, 1, 128, 256),
    _c("d4", 2, 2, 2, 0, 256, 512),
    _c("d5", 1, 1, 1, 0, 512, 256),
    _c("d6", 1, 1, 1, 0, 256, 128),
]

GAN_MODELS: dict[str, tuple[list[ConvLayer], list[ConvLayer]]] = {
    "3dgan": (GAN3D_G, GAN3D_D),
    "artgan": (ARTGAN_G, ARTGAN_D),
    "dcgan": (DCGAN_G, DCGAN_D),
    "discogan": (DISCOGAN_G, DISCOGAN_D),
    "gpgan": (GPGAN_G, GPGAN_D),
    "magan": (MAGAN_G, MAGAN_D),
}


def gan_layers(name: str) -> tuple[list[ConvLayer], list[ConvLayer]]:
    return GAN_MODELS[name]
