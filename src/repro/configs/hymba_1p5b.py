"""Hymba-1.5B — hybrid: parallel attention + Mamba heads in every block.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676].  Sliding-window attention everywhere except three
full-attention layers (first/middle/last); meta tokens are not modeled
(DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    ssm=True,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    local_window=1024,
    global_layers=(0, 15, 31),
    mlp_kind="swiglu",
))
