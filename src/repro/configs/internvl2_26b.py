"""InternVL2-26B — VLM: InternViT frontend (stubbed) + InternLM2 backbone.

Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821].  Per the assignment spec the modality frontend is a
STUB: ``input_specs()`` provides precomputed patch embeddings (256 tokens
at ViT hidden 3200, projected in-model to d_model).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    mlp_kind="swiglu",
    rope_theta=1e6,
    img_tokens=256,
    frontend_dim=3200,
))
