"""HuBERT-XLarge — encoder-only audio transformer (w2v2 architecture).

48L d_model=1280 16H d_ff=5120 vocab=504 (codebook targets)
[arXiv:2106.07447].  The waveform frontend (conv feature extractor) is a
STUB per the assignment spec: ``input_specs()`` provides precomputed frame
features (dim 512) which the model projects to d_model.  Encoder-only ⇒ no
decode shapes.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    mlp_kind="gelu",
    frontend_dim=512,
))
