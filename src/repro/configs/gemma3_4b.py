"""Gemma3-4B — dense transformer with 5:1 local:global attention, 128k.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, sliding window 1024
on local layers, global layers use rope_theta=1e6 [hf:google/gemma-3-*-pt].
The 5:1 interleave makes the arch sub-quadratic enough for long_500k decode
(global layers are linear-in-cache at decode).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    mlp_kind="geglu",
    tie_embeddings=True,
    local_window=1024,
    local_global_pattern=(5, 1),
    rope_theta=1e4,
))
