"""Mamba2-2.7B — attention-free SSM (state-space duality / SSD).

64L d_model=2560 vocab=50280, d_inner=2×d, headdim=64 (→ 80 SSM heads),
state=128, conv width 4, 1 B/C group [arXiv:2405.21060].
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=True,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    mlp_kind="none",
))
