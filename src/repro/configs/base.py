"""Architecture configuration schema + registry + input specs.

Every assigned architecture is a single `ArchConfig`; the model zoo
(`models/transformer.py`) consumes it directly.  Layer heterogeneity
(gemma3's 5:1 local:global, hymba's sparse global layers) is expressed as
*segments*: ``layer_segments() -> [(block_descriptors, repeat), ...]`` where
each segment is scanned over ``repeat`` and the descriptors inside are
unrolled (keeping HLO size ~O(#distinct descriptors), not O(#layers)).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

__all__ = ["ArchConfig", "BlockDesc", "ShapeSpec", "SHAPES", "register",
           "get_config", "list_configs", "REGISTRY"]


@dataclasses.dataclass(frozen=True)
class BlockDesc:
    """One decoder block position inside a segment."""
    mixer: Literal["attn", "mla", "ssm", "hybrid"] = "attn"
    mlp: Literal["swiglu", "geglu", "gelu", "moe", "none"] = "swiglu"
    window: int = 0          # 0 → global attention; >0 → sliding window
    rope_theta: float = 1e4  # per-block RoPE base (gemma3 differs L vs G)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encoder", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    # --- attention flavor ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    causal: bool = True               # False for encoder-only (hubert)
    local_window: int = 0             # >0 enables SWA blocks
    local_global_pattern: tuple[int, int] = (0, 0)   # (n_local, n_global)
    global_layers: tuple[int, ...] = ()  # explicit global positions (hymba)
    # --- MLA (minicpm3) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hymba) ---
    ssm: bool = False
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    # --- misc ---
    mlp_kind: Literal["swiglu", "geglu", "gelu", "none"] = "swiglu"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    img_tokens: int = 0               # VLM: stub patch embeddings prefix
    frontend_dim: int = 0             # audio/vlm stub feature dim
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        evenly over the model axis (padded logits are masked in the loss
        and at sampling time)."""
        return -(-self.vocab // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def block(self, **over) -> BlockDesc:
        base = dict(
            mixer="mla" if self.mla else ("ssm" if self.ssm and not over.get(
                "mixer") else "attn"),
            mlp="moe" if self.moe else self.mlp_kind,
            window=0, rope_theta=self.rope_theta)
        base.update(over)
        return BlockDesc(**base)

    def layer_segments(self) -> list[tuple[tuple[BlockDesc, ...], int]]:
        """Segments of (block descriptors, scan repeat count)."""
        L = self.n_layers
        if self.family == "hybrid" or self.global_layers:
            # Explicit sparse global positions; everything else local hybrid.
            segs: list[tuple[tuple[BlockDesc, ...], int]] = []
            gl = sorted(self.global_layers)
            pos = 0
            mixer = "hybrid" if self.family == "hybrid" else "attn"
            for g in gl:
                if g > pos:
                    segs.append(((self.block(mixer=mixer,
                                             window=self.local_window),), g - pos))
                segs.append(((self.block(mixer=mixer, window=0),), 1))
                pos = g + 1
            if pos < L:
                segs.append(((self.block(mixer=mixer,
                                         window=self.local_window),), L - pos))
            return segs
        nl, ng = self.local_global_pattern
        if nl and ng:
            group = (self.block(window=self.local_window),) * nl + (
                self.block(window=0, rope_theta=1e6),) * ng
            n_groups = L // (nl + ng)
            segs = [(group, n_groups)]
            rem = L - n_groups * (nl + ng)
            if rem:
                segs.append(((self.block(window=self.local_window),), rem))
            return segs
        return [((self.block(),), L)]

    @property
    def supports_decode(self) -> bool:
        return self.causal

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run 500k-token decode (SSM/hybrid/local)."""
        return (self.family in ("ssm", "hybrid")
                or self.local_global_pattern[0] > 0)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        # late import of the config modules
        import repro.configs.archs  # noqa: F401
    return REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs.archs  # noqa: F401
    return sorted(REGISTRY)


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch × shape) is a runnable dry-run cell, with reason."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "needs sub-quadratic attention (full-attention arch)"
    return True, ""
