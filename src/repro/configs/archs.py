"""Import-all module: registers every assigned architecture config."""

from repro.configs import (  # noqa: F401
    gemma3_4b,
    gemma_7b,
    hubert_xlarge,
    hymba_1p5b,
    internvl2_26b,
    llama4_scout,
    mamba2_2p7b,
    minicpm3_4b,
    olmoe_1b_7b,
    qwen15_32b,
)
