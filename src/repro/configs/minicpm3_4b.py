"""MiniCPM3-4B — dense MLA transformer.

62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448, MLA attention
[hf:openbmb/MiniCPM3-4B].  MLA ranks follow the HF config: q_lora 768,
kv_lora 256, qk_nope 64, qk_rope 32, v_head 64.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    mlp_kind="swiglu",
    rope_theta=1e4,
))
