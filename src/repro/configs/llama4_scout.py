"""Llama-4-Scout-17B-16E — MoE transformer (16 experts, top-1 + shared).

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048, every layer
MoE with one always-on shared expert [hf:meta-llama/Llama-4-Scout-17B-16E].
Treated as full-attention for shape-skip purposes (the chunked-attention
variant is not modeled), see DESIGN.md §Arch-applicability.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    moe=True,
    n_experts=16,
    top_k=1,
    expert_d_ff=8192,
    n_shared_experts=1,
    mlp_kind="swiglu",
    rope_theta=5e5,
))
