"""Gemma-7B — dense transformer, GeGLU, head_dim=256, tied embeddings.

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000 [arXiv:2403.08295].
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab=256000,
    head_dim=256,
    mlp_kind="geglu",
    tie_embeddings=True,
    rope_theta=1e4,
))
