"""Version-compat shims over moving JAX APIs.

The repo pins whatever JAX the image bakes in; a handful of APIs we use
were renamed across releases.  Every call site goes through this module so
a version bump is a one-file change:

* ``tpu_compiler_params`` — ``pltpu.CompilerParams`` (new spelling) vs
  ``pltpu.TPUCompilerParams`` (0.4.x spelling).
* ``shard_map`` — ``jax.shard_map`` with ``check_vma`` (new) vs
  ``jax.experimental.shard_map.shard_map`` with ``check_rep`` (0.4.x).
* ``lower_as_mlir`` — ``pl.lower_as_mlir`` (new) vs cross-platform export
  lowering (0.4.x), both yielding the Mosaic/TPU MLIR for inspection.
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["tpu_compiler_params", "shard_map", "lower_as_mlir"]


def tpu_compiler_params(*, dimension_semantics: Sequence[str]):
    """Build Pallas-TPU compiler params on either JAX spelling."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(dimension_semantics=tuple(dimension_semantics))


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` with replication/VMA checking toggled portably.

    The entry point (``jax.shard_map`` vs experimental) and the check
    kwarg (``check_vma`` vs ``check_rep``) were renamed in *different*
    releases, so both are probed independently."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


def lower_as_mlir(f, *args) -> str:
    """Lower ``f(*args)`` for the real TPU target and return the MLIR text
    (works from a CPU host: the kernel must *lower*, not run)."""
    if hasattr(pl, "lower_as_mlir"):
        return str(pl.lower_as_mlir(f, *args))
    from jax import export
    return export.export(jax.jit(f),
                         platforms=("tpu",))(*args).mlir_module()
