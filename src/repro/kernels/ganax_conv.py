"""GANAX unified conv/tconv Pallas TPU kernel (MIMD-SIMD over phases).

The kernel realizes the paper's architecture on TPU:

* **Grid dimension over phases** = the MIMD axis.  Each phase is one
  "microprogram": its tap count is *data-driven* (scalar-prefetched), so
  different grid steps execute loops of different length — the unified
  MIMD-SIMD execution at single-μop granularity.  A stride-1 convolution is
  the degenerate single-phase case (pure SIMD mode), so discriminator convs
  run through the *same* kernel with zero overhead — the paper's "without
  compromising conventional convolution" property.
* **Scalar prefetch tables** (`n_taps`, `tap_dy`, `tap_dx`) = the two-level
  μop buffer: the grid's phase id is the global-μop index field; the SMEM
  tables it selects are the local μop buffer contents.
* **Decoupled access-execute**: `BlockSpec.index_map`s + the in-kernel
  `pl.ds` offsets derived from the prefetched tables are the access
  μ-engine (they drive the double-buffered HBM→VMEM DMA pipeline ahead of
  compute — the paper's address FIFOs); the tap loop's MXU contractions are
  the address-free execute μ-engine.
* **Zero elimination**: the tap tables enumerate only consequential taps;
  inserted zeros are never fetched nor multiplied.

Layout contract (prepared by ``ops.py`` from the `PhaseSchedule`):

  x_pad   (B, Hp, Wp, Cin)   input, uniformly padded for all phases
  w_taps  (P, T, Cin, Cout)  per-phase gathered taps, zero-padded to T
  n_taps  (P,)               consequential taps per phase
  tap_dy / tap_dx (P, T)     input row/col offset per tap (≥ 0, into x_pad)
  bias    (1, Cout)          optional fused-epilogue bias (f32)
  out     (B, P, Qy, Qx, Cout) phase-major output planes (interleaved into
                              the final output by ops.py — a pure layout op)

**Fused epilogue**: when a bias vector and/or an ``activation`` name is
passed, the bias add and activation execute inside the accumulator
*flush* step (the last Cin tile of each output block), on the f32
accumulator, before the single cast+store to HBM.  Without fusion every
layer writes the raw accumulator to HBM only to re-read it for two
trivially fusable elementwise ops — one whole output-feature-map HBM
round-trip per GAN layer on the hot path.  The activation is a static
kernel parameter (each variant is its own compiled kernel), the bias
rides the existing DMA pipeline as one extra (1, block_cout) VMEM
block keyed on the Cout grid coordinate.

Tiling: grid = (B, P, Qy/bq, Cout/bc, Cin/bk); the full (padded) spatial
extent of one image is resident in VMEM per step (GAN feature maps are
small: ≤ ~70² × 128-channel tile ≈ 1.2 MiB in f32), while the *output*
rows are tiled by ``block_qy`` so the accumulator footprint is a free
parameter.  The MXU contraction is (bq·Qx, Cin)×(Cin, Cout) per tap.

The zero-pattern repetition the schedule exploits is rank-agnostic, so
the same design extends to volumetric (3-D) layers — the 3D-GAN
workload: :func:`ganax_conv3d_pallas` adds a depth axis to every table
(``tap_dz``), tiles output *planes* with ``block_qz`` alongside the
``block_qy`` row tiling, and walks a 6-D grid
(B, P, Qz/bz, Qy/bq, Cout/bc, Cin/bk).  The contraction becomes
(bz·bq·Qx, Cin)×(Cin, Cout) per tap; everything else — scalar-prefetched
μop tables, data-driven tap loops, zero elimination — is unchanged.

The block shapes (``block_qz`` for 3-D, ``block_qy``, ``block_cin``,
``block_cout``) are tunable parameters, not constants: the autotuning
planner (``repro.tune``) enumerates the valid divisors for a layer
geometry and measures them; the defaults (full Qz/Qy, 128-aligned
channel tiles) are the heuristic used when no plan exists.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["ganax_conv_kernel", "ganax_conv_pallas",
           "ganax_conv3d_kernel", "ganax_conv3d_pallas",
           "apply_epilogue_to_acc"]


def apply_epilogue_to_acc(acc, b_ref, activation: str,
                          leaky_slope: float):
    """Fused epilogue on the f32 accumulator: optional (1, block_cout)
    bias block broadcast over the flattened spatial rows, then a
    statically selected activation.  Shared by the planar and the
    volumetric kernel's flush steps."""
    if b_ref is not None:
        acc = acc + b_ref[...]                 # (rows, bco) + (1, bco)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "leaky_relu":
        acc = jnp.where(acc > 0, acc, leaky_slope * acc)
    elif activation == "tanh":
        acc = jnp.tanh(acc)
    return acc


def ganax_conv_kernel(
    # scalar-prefetch refs (SMEM)
    n_taps_ref, tap_dy_ref, tap_dx_ref,
    # tensor refs (VMEM blocks): x, w, optional epilogue bias, then the
    # output block and the f32 accumulator scratch
    x_ref, w_ref, *refs,
    bqy: int, qx: int, sy: int, sx: int, n_cin_tiles: int,
    activation: str = "none", leaky_slope: float = 0.2,
):
    """One grid step: (batch b, phase p, qy tile, cout tile, cin tile)."""
    if len(refs) == 3:
        b_ref, out_ref, acc_ref = refs
    else:
        (out_ref, acc_ref), b_ref = refs, None
    ph = pl.program_id(1)
    qb = pl.program_id(2)
    ci = pl.program_id(4)
    row0 = qb * bqy * sy          # first padded-input row of this qy tile

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = n_taps_ref[ph]

    def tap_body(t, _):
        dy = tap_dy_ref[ph, t]
        dx = tap_dx_ref[ph, t]
        # Access engine: strided window starting at (dy + row0, dx).  For
        # plain strided convs (sy/sx > 1) the window is subsampled
        # post-load.
        xt = x_ref[0, pl.ds(dy + row0, (bqy - 1) * sy + 1),
                   pl.ds(dx, (qx - 1) * sx + 1), :]
        xt = xt[::sy, ::sx, :] if (sy > 1 or sx > 1) else xt
        wt = w_ref[0, t]                       # (cin_t, cout_t)
        # Execute engine: MXU contraction over the channel tile.
        acc_ref[...] += jax.lax.dot_general(
            xt.reshape(bqy * qx, xt.shape[-1]), wt,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return ()

    jax.lax.fori_loop(0, n, tap_body, ())

    @pl.when(ci == n_cin_tiles - 1)
    def _flush():
        acc = apply_epilogue_to_acc(acc_ref[...], b_ref, activation,
                                    leaky_slope)
        out_ref[0, 0] = acc.reshape(bqy, qx, -1).astype(out_ref.dtype)


def ganax_conv_pallas(x_pad: jax.Array, w_taps: jax.Array,
                      n_taps: jax.Array, tap_dy: jax.Array,
                      tap_dx: jax.Array, out_strides: tuple[int, int],
                      qy: int, qx: int,
                      block_cin: int = 128, block_cout: int = 128,
                      block_qy: int | None = None,
                      bias: jax.Array | None = None,
                      activation: str = "none", leaky_slope: float = 0.2,
                      out_dtype=None, interpret: bool = False) -> jax.Array:
    """Invoke the unified kernel.  See module docstring for layout;
    ``bias`` is the fused-epilogue (1, Cout) vector (or None) and
    ``activation``/``leaky_slope`` the fused activation."""
    b, hp, wp, cin = x_pad.shape
    p, t, cin_w, cout = w_taps.shape
    block_qy = qy if block_qy is None else block_qy
    assert cin_w == cin, (cin_w, cin)
    assert cin % block_cin == 0 and cout % block_cout == 0, \
        (cin, cout, block_cin, block_cout)
    assert qy % block_qy == 0, (qy, block_qy)
    n_ci = cin // block_cin
    n_co = cout // block_cout
    n_qb = qy // block_qy
    out_dtype = out_dtype or x_pad.dtype
    sy, sx = out_strides

    grid = (b, p, n_qb, n_co, n_ci)
    kernel = functools.partial(ganax_conv_kernel, bqy=block_qy, qx=qx,
                               sy=sy, sx=sx, n_cin_tiles=n_ci,
                               activation=activation,
                               leaky_slope=leaky_slope)
    in_specs = [
        pl.BlockSpec((1, hp, wp, block_cin),
                     lambda bi, ph, qb, co, ci, *_: (bi, 0, 0, ci)),
        pl.BlockSpec((1, t, block_cin, block_cout),
                     lambda bi, ph, qb, co, ci, *_: (ph, 0, ci, co)),
    ]
    operands = [x_pad, w_taps]
    if bias is not None:
        assert bias.shape == (1, cout), (bias.shape, cout)
        in_specs.append(pl.BlockSpec(
            (1, block_cout), lambda bi, ph, qb, co, ci, *_: (0, co)))
        operands.append(bias)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, block_qy, qx, block_cout),
            lambda bi, ph, qb, co, ci, *_: (bi, ph, qb, 0, co)),
        scratch_shapes=[pltpu.VMEM((block_qy * qx, block_cout),
                                   jnp.float32)],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, p, qy, qx, cout), out_dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary", "arbitrary"),
        ),
    )
    return fn(n_taps, tap_dy, tap_dx, *operands)


def ganax_conv3d_kernel(
    # scalar-prefetch refs (SMEM)
    n_taps_ref, tap_dz_ref, tap_dy_ref, tap_dx_ref,
    # tensor refs (VMEM blocks): x, w, optional epilogue bias, then the
    # output block and the f32 accumulator scratch
    x_ref, w_ref, *refs,
    bqz: int, bqy: int, qx: int, sz: int, sy: int, sx: int,
    n_cin_tiles: int, activation: str = "none", leaky_slope: float = 0.2,
):
    """One grid step: (batch b, phase p, qz tile, qy tile, cout, cin)."""
    if len(refs) == 3:
        b_ref, out_ref, acc_ref = refs
    else:
        (out_ref, acc_ref), b_ref = refs, None
    ph = pl.program_id(1)
    zb = pl.program_id(2)
    qb = pl.program_id(3)
    ci = pl.program_id(5)
    pl0 = zb * bqz * sz           # first padded-input plane of this qz tile
    row0 = qb * bqy * sy          # first padded-input row of this qy tile

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = n_taps_ref[ph]

    def tap_body(t, _):
        dz = tap_dz_ref[ph, t]
        dy = tap_dy_ref[ph, t]
        dx = tap_dx_ref[ph, t]
        # Access engine: strided volume starting at (dz + pl0, dy + row0,
        # dx).  For plain strided convs the volume is subsampled post-load.
        xt = x_ref[0, pl.ds(dz + pl0, (bqz - 1) * sz + 1),
                   pl.ds(dy + row0, (bqy - 1) * sy + 1),
                   pl.ds(dx, (qx - 1) * sx + 1), :]
        xt = xt[::sz, ::sy, ::sx, :] if (sz > 1 or sy > 1 or sx > 1) else xt
        wt = w_ref[0, t]                       # (cin_t, cout_t)
        # Execute engine: MXU contraction over the channel tile.
        acc_ref[...] += jax.lax.dot_general(
            xt.reshape(bqz * bqy * qx, xt.shape[-1]), wt,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return ()

    jax.lax.fori_loop(0, n, tap_body, ())

    @pl.when(ci == n_cin_tiles - 1)
    def _flush():
        acc = apply_epilogue_to_acc(acc_ref[...], b_ref, activation,
                                    leaky_slope)
        out_ref[0, 0] = acc.reshape(bqz, bqy, qx, -1) \
            .astype(out_ref.dtype)


def ganax_conv3d_pallas(x_pad: jax.Array, w_taps: jax.Array,
                        n_taps: jax.Array, tap_dz: jax.Array,
                        tap_dy: jax.Array, tap_dx: jax.Array,
                        out_strides: tuple[int, int, int],
                        qz: int, qy: int, qx: int,
                        block_cin: int = 128, block_cout: int = 128,
                        block_qz: int | None = None,
                        block_qy: int | None = None,
                        bias: jax.Array | None = None,
                        activation: str = "none",
                        leaky_slope: float = 0.2,
                        out_dtype=None, interpret: bool = False
                        ) -> jax.Array:
    """Invoke the volumetric kernel.  See module docstring for layout;
    the fused epilogue (``bias``/``activation``/``leaky_slope``) is
    identical to the planar kernel's."""
    b, dp, hp, wp, cin = x_pad.shape
    p, t, cin_w, cout = w_taps.shape
    block_qz = qz if block_qz is None else block_qz
    block_qy = qy if block_qy is None else block_qy
    assert cin_w == cin, (cin_w, cin)
    assert cin % block_cin == 0 and cout % block_cout == 0, \
        (cin, cout, block_cin, block_cout)
    assert qz % block_qz == 0 and qy % block_qy == 0, \
        (qz, block_qz, qy, block_qy)
    n_ci = cin // block_cin
    n_co = cout // block_cout
    n_zb = qz // block_qz
    n_qb = qy // block_qy
    out_dtype = out_dtype or x_pad.dtype
    sz, sy, sx = out_strides

    grid = (b, p, n_zb, n_qb, n_co, n_ci)
    kernel = functools.partial(ganax_conv3d_kernel, bqz=block_qz,
                               bqy=block_qy, qx=qx, sz=sz, sy=sy, sx=sx,
                               n_cin_tiles=n_ci, activation=activation,
                               leaky_slope=leaky_slope)
    in_specs = [
        pl.BlockSpec((1, dp, hp, wp, block_cin),
                     lambda bi, ph, zb, qb, co, ci, *_:
                     (bi, 0, 0, 0, ci)),
        pl.BlockSpec((1, t, block_cin, block_cout),
                     lambda bi, ph, zb, qb, co, ci, *_:
                     (ph, 0, ci, co)),
    ]
    operands = [x_pad, w_taps]
    if bias is not None:
        assert bias.shape == (1, cout), (bias.shape, cout)
        in_specs.append(pl.BlockSpec(
            (1, block_cout),
            lambda bi, ph, zb, qb, co, ci, *_: (0, co)))
        operands.append(bias)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, block_qz, block_qy, qx, block_cout),
            lambda bi, ph, zb, qb, co, ci, *_: (bi, ph, zb, qb, 0, co)),
        scratch_shapes=[pltpu.VMEM((block_qz * block_qy * qx, block_cout),
                                   jnp.float32)],
    )
    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, p, qz, qy, qx, cout), out_dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary", "arbitrary", "arbitrary"),
        ),
    )
    return fn(n_taps, tap_dz, tap_dy, tap_dx, *operands)
