"""Pallas TPU flash-attention (forward) kernel.

Beyond-paper optimization (EXPERIMENTS.md §Perf): the dominant roofline
term for the attention-heavy cells is HBM traffic from materialized
(S × block_k) score tensors — ~6 passes over S²·H elements per layer.  This
kernel keeps the running (max, denom, accumulator) and each score block in
VMEM: HBM traffic collapses to the q/k/v/out tensors themselves.

Grid: (batch·heads, q_blocks); the kv loop runs inside the kernel with
online softmax.  Causal masking skips fully-masked kv blocks via the loop
bound (the same data-driven-trip-count mechanism the GANAX conv kernel uses
for its per-phase microprograms).  Validated against the pure-jnp oracle in
interpret mode; ``ops`` wrapper falls back to the jnp path off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_k,
               causal, sm_scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale       # (bq, d)
    n_kv = seq_k // block_k
    if causal:
        # kv blocks strictly below the diagonal block are fully visible;
        # the diagonal block needs masking; later blocks are skipped.
        n_live = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k,
                             n_kv)
    else:
        n_live = n_kv

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, block_q=512,
                           block_k=512, interpret=False):
    """q (B,S,H,hd), k/v (B,T,Hk,hd) with Hk == H (expand GQA first).

    Returns (B,S,H,hd).  Forward only — pair with jax.checkpoint for
    training (backward recomputes through the kernel).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    dv = v.shape[-1]
    assert k.shape[2] == h, "expand GQA to MHA before the kernel"
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    # (B,S,H,d) → (B*H, S, d)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, t, dv)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, seq_k=t,
        causal=causal, sm_scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, t, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, t, dv), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), q.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
