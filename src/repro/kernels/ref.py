"""Pure-jnp oracles for the GANAX kernels.

These are the ground truth the Pallas kernels are validated against in
``tests/test_kernels.py`` (shape/dtype sweeps, interpret mode).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.tconv import _dim_numbers, accum_conv, tconv_zero_insert

__all__ = ["tconv_ref", "conv_ref"]


def tconv_ref(x: jax.Array, w: jax.Array, strides: Sequence[int],
              paddings: Sequence[int]) -> jax.Array:
    """Transposed convolution oracle (channels-last, PyTorch geometry).

    Implemented via the zero-insertion definition — deliberately the
    *naive* formulation, independent from the polyphase code under test.
    """
    return tconv_zero_insert(x, w, strides, paddings)


def conv_ref(x: jax.Array, w: jax.Array, strides: Sequence[int],
             paddings: Sequence[int]) -> jax.Array:
    """Plain (discriminator) convolution oracle: correlation, stride s,
    symmetric padding p."""
    nd = x.ndim - 2
    pads = tuple((p, p) for p in paddings)
    # accum_conv: f32 accumulation with a defined transpose at every
    # storage precision (see core/tconv.py)
    return accum_conv(
        x, w, window_strides=tuple(strides), padding=pads,
        dimension_numbers=_dim_numbers(nd),
        preferred_element_type=jnp.float32).astype(x.dtype)
