"""Jit-ready wrappers around the GANAX Pallas kernel.

These are the *kernel backends* of the unified dispatch layer
(`core.dataflow`): ``ganax_conv_transpose`` / ``ganax_conv`` execute one
(transposed) convolution through the Pallas MIMD-SIMD kernel, either
compiled for TPU or in interpret mode (exact semantics, Python speed).
Both ranks the kernel implements — planar (2-D) and volumetric (3-D,
the 3D-GAN workload) — dispatch from here to the matching
`kernels.ganax_conv` entry point.

They are registered in `core.dataflow` as the ``pallas-tpu`` and
``pallas-interpret`` backends — model code should not call them directly
but go through ``dataflow.tconv`` / ``dataflow.conv`` with a
``DataflowPolicy``, which adds auto-selection (platform/rank), the cached
μop compilation, and the custom VJP that makes these kernels trainable.

The static μop compilation (tap tables, per-phase weight-gather indices,
uniform padding plan) comes from ``core.dataflow.compile_uops`` /
``compile_conv_uops`` — LRU-cached on layer geometry, so retracing a
repeated layer never re-runs the scheduler.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import (CompiledUops, Epilogue, compile_conv_uops,
                                 compile_uops)
from repro.core.dataflow import pallas_kernel_supported as kernel_supported
from repro.core.tconv import interleave_phases
from repro.kernels.ganax_conv import ganax_conv3d_pallas, ganax_conv_pallas

__all__ = ["ganax_conv_transpose", "ganax_conv", "kernel_supported",
           "default_blocks", "resolve_blocks"]


def _channel_blocks(cin: int, cout: int) -> tuple[int, int]:
    """MXU-aligned channel tiles (multiples of 128 when possible)."""
    bc_in = 128 if cin % 128 == 0 else cin
    bc_out = 128 if cout % 128 == 0 else cout
    return bc_in, bc_out


def _lead_extents(q_lead) -> tuple[int, ...]:
    """Normalize the tiled leading phase-plane extents: a bare ``qy`` int
    (2-D) or the ``(qz, qy)`` pair (3-D)."""
    if isinstance(q_lead, (int, np.integer)):
        return (int(q_lead),)
    return tuple(int(v) for v in q_lead)


def default_blocks(q_lead, cin: int, cout: int) -> tuple[int, ...]:
    """The heuristic block shapes used when no tuned plan overrides them:
    full leading phase-plane extents, 128-aligned channels.  ``q_lead``
    is ``qy`` for 2-D layers and ``(qz, qy)`` for volumetric ones, giving
    ``(block_qy, block_cin, block_cout)`` respectively
    ``(block_qz, block_qy, block_cin, block_cout)``."""
    return _lead_extents(q_lead) + _channel_blocks(cin, cout)


def resolve_blocks(blocks, q_lead, cin: int, cout: int
                   ) -> tuple[int, ...]:
    """Validate explicit kernel tile shapes — the
    (block_qy, block_cin, block_cout) triple for 2-D layers or the
    (block_qz, block_qy, block_cin, block_cout) quadruple for 3-D — or
    fall back to :func:`default_blocks` when ``blocks`` is None."""
    lead = _lead_extents(q_lead)
    if blocks is None:
        return default_blocks(lead, cin, cout)
    names = ("block_qz", "block_qy")[-len(lead):] + \
        ("block_cin", "block_cout")
    arity = "triple" if len(names) == 3 else "quadruple"
    try:
        vals = tuple(int(v) for v in blocks)
        if len(vals) != len(names):
            raise ValueError
    except (TypeError, ValueError):
        raise ValueError(
            f"blocks must be a ({', '.join(names)}) {arity}, "
            f"got {blocks!r}") from None
    planes = {"block_qz": "depth qz", "block_qy": "height qy"}
    for name, v, extent in zip(names, vals, lead + (cin, cout)):
        if v <= 0 or extent % v != 0:
            what = (f"the phase-plane {planes[name]}={extent}"
                    if name in planes
                    else f"{name.split('_')[1]}={extent}")
            raise ValueError(f"{name}={v} must divide {what}")
    return vals


def _gather_weights(w: jax.Array, u: CompiledUops) -> jax.Array:
    """Per-phase weight taps (P, T, Cin, Cout); padding taps get zeros.

    This is the only traced part of the μop prep — it depends on the
    weight *values*; the gather indices themselves are cached."""
    cin, cout = w.shape[-2:]
    p, t_max = u.k_idx.shape
    w_flat = w.reshape(-1, cin, cout)
    w_taps = jnp.take(w_flat, jnp.asarray(u.k_idx.reshape(-1)), axis=0)
    w_taps = w_taps.reshape(p, t_max, cin, cout)
    return jnp.where(jnp.asarray(u.valid)[:, :, None, None], w_taps, 0)


def _check_rank(nd: int, route: str) -> None:
    if not kernel_supported(nd):
        raise ValueError(f"the Pallas kernel supports 2-D and 3-D spatial "
                         f"inputs, got {nd}-D; route through "
                         f"dataflow.{route} for automatic fallback")


def _epilogue_args(epilogue: Epilogue | None, bias, cout: int) -> dict:
    """Kernel kwargs for one fused epilogue (validated against it).
    As in the dataflow layer, a bare ``bias=`` with no epilogue means a
    plain fused bias add."""
    if epilogue is None:
        epilogue = Epilogue(bias=bias is not None)
    if epilogue.bias != (bias is not None):
        raise ValueError(f"epilogue.bias={epilogue.bias} but "
                         f"bias {'missing' if bias is None else 'passed'}")
    b2d = None
    if bias is not None:
        bias = jnp.asarray(bias)
        if bias.shape != (cout,):
            raise ValueError(f"bias must have shape (cout,)=({cout},), "
                             f"got {tuple(bias.shape)}")
        # the kernel adds on the f32 accumulator; (1, Cout) so the VMEM
        # block keyed on the Cout grid coordinate stays rank-2
        b2d = bias.astype(jnp.float32)[None, :]
    return {"bias": b2d, "activation": epilogue.activation,
            "leaky_slope": epilogue.leaky_slope}


def _kernel_call(x_pad, w_taps, u, *, out_strides, q_sizes, blocks,
                 out_dtype, interpret, epilogue=None, bias=None):
    """Dispatch one prepared invocation to the rank-matching kernel."""
    ep_args = _epilogue_args(epilogue, bias, int(w_taps.shape[-1]))
    if len(q_sizes) == 2:
        bqy, bci, bco = blocks
        return ganax_conv_pallas(
            x_pad, w_taps, jnp.asarray(u.n_taps), jnp.asarray(u.tap_dy),
            jnp.asarray(u.tap_dx), out_strides=out_strides,
            qy=q_sizes[0], qx=q_sizes[1], block_cin=bci, block_cout=bco,
            block_qy=bqy, out_dtype=out_dtype, interpret=interpret,
            **ep_args)
    bqz, bqy, bci, bco = blocks
    return ganax_conv3d_pallas(
        x_pad, w_taps, jnp.asarray(u.n_taps), jnp.asarray(u.tap_dz),
        jnp.asarray(u.tap_dy), jnp.asarray(u.tap_dx),
        out_strides=out_strides, qz=q_sizes[0], qy=q_sizes[1],
        qx=q_sizes[2], block_cin=bci, block_cout=bco, block_qz=bqz,
        block_qy=bqy, out_dtype=out_dtype, interpret=interpret,
        **ep_args)


def ganax_conv_transpose(x: jax.Array, w: jax.Array,
                         strides: Sequence[int], paddings: Sequence[int],
                         *, interpret: bool | None = None,
                         blocks: Sequence[int] | None = None,
                         epilogue: Epilogue | None = None,
                         bias: jax.Array | None = None) -> jax.Array:
    """Transposed convolution through the unified GANAX kernel.

    x: (N, *spatial, Cin) channels-last; w: (K..., Cin, Cout), with two
    or three spatial dims.  ``blocks`` optionally pins the kernel tile
    shapes — (block_qy, block_cin, block_cout) for 2-D,
    (block_qz, block_qy, block_cin, block_cout) for 3-D; each must
    divide its extent.  ``None`` uses the heuristic defaults.

    ``epilogue``/``bias`` fuse a bias add + activation into the kernel's
    accumulator flush (phases whose μop list is empty — kernel < stride
    — still get the epilogue: their outputs are legitimately
    ``act(0 + b)``).  The epilogue commutes with the phase interleave
    (it is elementwise), so it runs on the phase-major planes before the
    pure-layout reorganization.
    """
    nd = x.ndim - 2
    _check_rank(nd, "tconv")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    strides = tuple(strides)
    paddings = tuple(paddings)
    u = compile_uops(x.shape[1:1 + nd], w.shape[:nd], strides, paddings)
    sched = u.schedule

    cin, cout = w.shape[-2], w.shape[-1]
    blocks = resolve_blocks(blocks, u.q_sizes[:-1], cin, cout)
    x_pad = jnp.pad(x, ((0, 0),) + u.pad + ((0, 0),))
    w_taps = _gather_weights(w, u)

    out_pm = _kernel_call(x_pad, w_taps, u, out_strides=(1,) * nd,
                          q_sizes=u.q_sizes, blocks=blocks,
                          out_dtype=x.dtype, interpret=interpret,
                          epilogue=epilogue, bias=bias)
    # out_pm: (B, P, *Q, Cout) in schedule.phase_order; interleave.
    phase_planes = {}
    for row, flat in enumerate(sched.phase_order):
        phases = sched.phase_tuple(flat)
        crop = tuple(slice(0, pd.out_size) for pd in sched.phase_dims(flat))
        phase_planes[phases] = out_pm[(slice(None), row) + crop]
    if sched.n_phases == 1:
        return phase_planes[(0,) * nd]
    return interleave_phases(phase_planes, sched)


def ganax_conv(x: jax.Array, w: jax.Array, strides: Sequence[int],
               paddings: Sequence[int], *,
               interpret: bool | None = None,
               blocks: Sequence[int] | None = None,
               epilogue: Epilogue | None = None,
               bias: jax.Array | None = None) -> jax.Array:
    """Plain (strided) convolution through the same kernel — the paper's
    SIMD mode: a single phase whose taps are the full kernel.
    ``epilogue``/``bias`` as in :func:`ganax_conv_transpose`."""
    nd = x.ndim - 2
    _check_rank(nd, "conv")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    strides = tuple(strides)
    paddings = tuple(paddings)
    u = compile_conv_uops(x.shape[1:1 + nd], w.shape[:nd], strides,
                          paddings)

    cin, cout = w.shape[-2], w.shape[-1]
    x_pad = jnp.pad(x, ((0, 0),) + u.pad + ((0, 0),))
    w_taps = w.reshape(1, -1, cin, cout)
    blocks = resolve_blocks(blocks, u.out_sizes[:-1], cin, cout)
    out_pm = _kernel_call(x_pad, w_taps, u, out_strides=strides,
                          q_sizes=u.out_sizes, blocks=blocks,
                          out_dtype=x.dtype, interpret=interpret,
                          epilogue=epilogue, bias=bias)
    return out_pm[:, 0]
