"""Jit-ready wrappers around the GANAX Pallas kernel.

These are the *kernel backends* of the unified dispatch layer
(`core.dataflow`): ``ganax_conv_transpose`` / ``ganax_conv`` execute one
(transposed) convolution through the Pallas MIMD-SIMD kernel, either
compiled for TPU or in interpret mode (exact semantics, Python speed).

They are registered in `core.dataflow` as the ``pallas-tpu`` and
``pallas-interpret`` backends — model code should not call them directly
but go through ``dataflow.tconv`` / ``dataflow.conv`` with a
``DataflowPolicy``, which adds auto-selection (platform/rank), the cached
μop compilation, and the custom VJP that makes these kernels trainable.

The static μop compilation (tap tables, per-phase weight-gather indices,
uniform padding plan) comes from ``core.dataflow.compile_uops`` /
``compile_conv_uops`` — LRU-cached on layer geometry, so retracing a
repeated layer never re-runs the scheduler.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.dataflow import (CompiledUops, compile_conv_uops,
                                 compile_uops)
from repro.core.dataflow import pallas_kernel_supported as kernel_supported
from repro.core.tconv import interleave_phases
from repro.kernels.ganax_conv import ganax_conv_pallas

__all__ = ["ganax_conv_transpose", "ganax_conv", "kernel_supported",
           "default_blocks", "resolve_blocks"]


def _channel_blocks(cin: int, cout: int) -> tuple[int, int]:
    """MXU-aligned channel tiles (multiples of 128 when possible)."""
    bc_in = 128 if cin % 128 == 0 else cin
    bc_out = 128 if cout % 128 == 0 else cout
    return bc_in, bc_out


def default_blocks(qy: int, cin: int, cout: int) -> tuple[int, int, int]:
    """The heuristic (block_qy, block_cin, block_cout) used when no tuned
    plan overrides them: full output-row extent, 128-aligned channels."""
    return (qy,) + _channel_blocks(cin, cout)


def resolve_blocks(blocks, qy: int, cin: int, cout: int
                   ) -> tuple[int, int, int]:
    """Validate an explicit (block_qy, block_cin, block_cout) triple, or
    fall back to :func:`default_blocks` when ``blocks`` is None."""
    if blocks is None:
        return default_blocks(qy, cin, cout)
    try:
        bqy, bci, bco = (int(v) for v in blocks)
    except (TypeError, ValueError):
        raise ValueError(
            f"blocks must be a (block_qy, block_cin, block_cout) triple, "
            f"got {blocks!r}") from None
    if bqy <= 0 or qy % bqy != 0:
        raise ValueError(f"block_qy={bqy} must divide the phase-plane "
                         f"height qy={qy}")
    if bci <= 0 or cin % bci != 0:
        raise ValueError(f"block_cin={bci} must divide cin={cin}")
    if bco <= 0 or cout % bco != 0:
        raise ValueError(f"block_cout={bco} must divide cout={cout}")
    return bqy, bci, bco


def _gather_weights(w: jax.Array, u: CompiledUops) -> jax.Array:
    """Per-phase weight taps (P, T, Cin, Cout); padding taps get zeros.

    This is the only traced part of the μop prep — it depends on the
    weight *values*; the gather indices themselves are cached."""
    kh, kw, cin, cout = w.shape
    p, t_max = u.k_idx.shape
    w_flat = w.reshape(kh * kw, cin, cout)
    w_taps = jnp.take(w_flat, jnp.asarray(u.k_idx.reshape(-1)), axis=0)
    w_taps = w_taps.reshape(p, t_max, cin, cout)
    return jnp.where(jnp.asarray(u.valid)[:, :, None, None], w_taps, 0)


def ganax_conv_transpose(x: jax.Array, w: jax.Array,
                         strides: Sequence[int], paddings: Sequence[int],
                         *, interpret: bool | None = None,
                         blocks: Sequence[int] | None = None) -> jax.Array:
    """Transposed convolution through the unified GANAX kernel.

    x: (N, H, W, Cin) channels-last; w: (KH, KW, Cin, Cout).
    ``blocks`` optionally pins the kernel tile shapes as a
    (block_qy, block_cin, block_cout) triple (each must divide its
    extent); ``None`` uses the heuristic defaults.
    """
    nd = x.ndim - 2
    if not kernel_supported(nd):
        raise ValueError(f"the Pallas kernel supports 2-D spatial inputs, "
                         f"got {nd}-D; route through dataflow.tconv for "
                         f"automatic fallback")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    strides = tuple(strides)
    paddings = tuple(paddings)
    u = compile_uops(x.shape[1:3], w.shape[:2], strides, paddings)
    sched = u.schedule

    qy, qx = u.q_sizes
    cin, cout = w.shape[-2], w.shape[-1]
    bqy, bci, bco = resolve_blocks(blocks, qy, cin, cout)
    x_pad = jnp.pad(x, ((0, 0), u.pad[0], u.pad[1], (0, 0)))
    w_taps = _gather_weights(w, u)

    out_pm = ganax_conv_pallas(x_pad, w_taps, jnp.asarray(u.n_taps),
                               jnp.asarray(u.tap_dy), jnp.asarray(u.tap_dx),
                               out_strides=(1, 1), qy=qy, qx=qx,
                               block_cin=bci, block_cout=bco, block_qy=bqy,
                               out_dtype=x.dtype, interpret=interpret)
    # out_pm: (B, P, Qy, Qx, Cout) in schedule.phase_order; interleave.
    phase_planes = {}
    for row, flat in enumerate(sched.phase_order):
        phases = sched.phase_tuple(flat)
        oy, ox = (pd.out_size for pd in sched.phase_dims(flat))
        phase_planes[phases] = out_pm[:, row, :oy, :ox, :]
    if sched.n_phases == 1:
        return phase_planes[(0, 0)]
    return interleave_phases(phase_planes, sched)


def ganax_conv(x: jax.Array, w: jax.Array, strides: Sequence[int],
               paddings: Sequence[int], *,
               interpret: bool | None = None,
               blocks: Sequence[int] | None = None) -> jax.Array:
    """Plain (strided) convolution through the same kernel — the paper's
    SIMD mode: a single phase whose taps are the full kernel."""
    nd = x.ndim - 2
    if not kernel_supported(nd):
        raise ValueError(f"the Pallas kernel supports 2-D spatial inputs, "
                         f"got {nd}-D; route through dataflow.conv for "
                         f"automatic fallback")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    strides = tuple(strides)
    paddings = tuple(paddings)
    u = compile_conv_uops(x.shape[1:3], w.shape[:2], strides, paddings)

    kh, kw, cin, cout = w.shape
    qy, qx = u.out_sizes
    x_pad = jnp.pad(x, ((0, 0), u.pad[0], u.pad[1], (0, 0)))
    w_taps = w.reshape(1, kh * kw, cin, cout)
    bqy, bci, bco = resolve_blocks(blocks, qy, cin, cout)
    out_pm = ganax_conv_pallas(x_pad, w_taps, jnp.asarray(u.n_taps),
                               jnp.asarray(u.tap_dy), jnp.asarray(u.tap_dx),
                               out_strides=tuple(strides), qy=qy, qx=qx,
                               block_cin=bci, block_cout=bco, block_qy=bqy,
                               out_dtype=x.dtype, interpret=interpret)
    return out_pm[:, 0]
