"""Jit-ready wrappers around the GANAX Pallas kernel.

``ganax_conv_transpose`` / ``ganax_conv`` are the public entry points used
by the model layer (`models/gan.py`).  They perform the *static* μop
compilation (via ``core.scheduler``) at trace time — tap tables, uniform
padding, per-phase weight gathering — then invoke the unified Pallas kernel
and interleave the phase-major result.

On non-TPU backends the kernel runs in interpret mode (exact semantics,
Python-speed); set ``force_pallas=False`` to dispatch to the pure-JAX
polyphase path (`core.tconv.tconv_ganax`) instead, which is the production
fallback for shapes the kernel doesn't support (3-D, ragged channels).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import PhaseSchedule, make_schedule
from repro.core.tconv import interleave_phases, tconv_ganax
from repro.kernels.ganax_conv import ganax_conv_pallas

__all__ = ["ganax_conv_transpose", "ganax_conv", "kernel_supported"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _channel_blocks(cin: int, cout: int) -> tuple[int, int]:
    """MXU-aligned channel tiles (multiples of 128 when possible)."""
    bc_in = 128 if cin % 128 == 0 else cin
    bc_out = 128 if cout % 128 == 0 else cout
    return bc_in, bc_out


def kernel_supported(nd: int) -> bool:
    return nd == 2


def _prepare(x, w, sched: PhaseSchedule, extra_slice: int,
             qy: int, qx: int):
    """Static 'μop compilation': pad input, gather per-phase taps."""
    tables = sched.tap_tables()
    p = sched.n_phases
    t_max = int(tables["tap_dy"].shape[1]) if "tap_dy" in tables else None
    # tap_tables returns tap_dx with shape (P, T, D); split per dim.
    tap_off = tables["tap_dx"]  # (P, T, 2)
    tap_k = tables["tap_k"]     # (P, T, 2)
    n_taps = tables["n_taps"]   # (P,)
    t_max = tap_off.shape[1]

    # Uniform padding + extra so every (dy + qy*sy) slice stays in bounds.
    (py_lo, py_hi), (px_lo, px_hi) = sched.uniform_padding()
    max_dy = int(tap_off[..., 0].max())
    max_dx = int(tap_off[..., 1].max())
    hp_needed = max_dy + extra_slice * (qy - 1) + 1
    wp_needed = max_dx + extra_slice * (qx - 1) + 1
    hp0 = x.shape[1] + py_lo + py_hi
    wp0 = x.shape[2] + px_lo + px_hi
    pad_y = (py_lo, py_hi + max(0, hp_needed - hp0))
    pad_x = (px_lo, px_hi + max(0, wp_needed - wp0))
    x_pad = jnp.pad(x, ((0, 0), pad_y, pad_x, (0, 0)))

    # Gather per-phase weights: (P, T, Cin, Cout); padding taps get zeros.
    kh, kw, cin, cout = w.shape
    w_flat = w.reshape(kh * kw, cin, cout)
    k_idx = tap_k[..., 0] * kw + tap_k[..., 1]           # (P, T)
    valid = (np.arange(t_max)[None, :] < n_taps[:, None])
    k_idx = np.where(valid, k_idx, 0)
    w_taps = jnp.take(w_flat, jnp.asarray(k_idx.reshape(-1)), axis=0)
    w_taps = w_taps.reshape(p, t_max, cin, cout)
    w_taps = jnp.where(jnp.asarray(valid)[:, :, None, None], w_taps, 0)
    return (x_pad, w_taps, jnp.asarray(n_taps),
            jnp.asarray(tap_off[..., 0]), jnp.asarray(tap_off[..., 1]))


def ganax_conv_transpose(x: jax.Array, w: jax.Array,
                         strides: Sequence[int], paddings: Sequence[int],
                         *, interpret: bool | None = None,
                         force_pallas: bool | None = None) -> jax.Array:
    """Transposed convolution through the unified GANAX kernel.

    x: (N, H, W, Cin) channels-last; w: (KH, KW, Cin, Cout).
    """
    nd = x.ndim - 2
    strides = tuple(strides)
    paddings = tuple(paddings)
    sched = make_schedule(x.shape[1:1 + nd], w.shape[:nd], strides, paddings)
    use_pallas = (kernel_supported(nd) if force_pallas is None
                  else force_pallas)
    if not use_pallas:
        return tconv_ganax(x, w, strides, paddings, schedule=sched)
    if interpret is None:
        interpret = not _on_tpu()

    qy, qx = (-(-o // s) for o, s in zip(sched.out_sizes, strides))
    cin, cout = w.shape[-2], w.shape[-1]
    bci, bco = _channel_blocks(cin, cout)
    x_pad, w_taps, n_taps, tap_dy, tap_dx = _prepare(x, w, sched, 1, qy, qx)

    out_pm = ganax_conv_pallas(x_pad, w_taps, n_taps, tap_dy, tap_dx,
                               out_strides=(1, 1), qy=qy, qx=qx,
                               block_cin=bci, block_cout=bco,
                               out_dtype=x.dtype, interpret=interpret)
    # out_pm: (B, P, Qy, Qx, Cout) in schedule.phase_order; interleave.
    phase_planes = {}
    for row, flat in enumerate(sched.phase_order):
        phases = sched.phase_tuple(flat)
        oy, ox = (pd.out_size for pd in sched.phase_dims(flat))
        phase_planes[phases] = out_pm[:, row, :oy, :ox, :]
    if sched.n_phases == 1:
        return phase_planes[(0, 0)]
    return interleave_phases(phase_planes, sched)


def ganax_conv(x: jax.Array, w: jax.Array, strides: Sequence[int],
               paddings: Sequence[int], *, interpret: bool | None = None,
               force_pallas: bool | None = None) -> jax.Array:
    """Plain (strided) convolution through the same kernel — the paper's
    SIMD mode: a single phase whose taps are the full kernel."""
    nd = x.ndim - 2
    strides = tuple(strides)
    paddings = tuple(paddings)
    use_pallas = (kernel_supported(nd) if force_pallas is None
                  else force_pallas)
    if not use_pallas:
        from repro.kernels.ref import conv_ref
        return conv_ref(x, w, strides, paddings)
    if interpret is None:
        interpret = not _on_tpu()

    kh, kw, cin, cout = w.shape
    sy, sx = strides
    py, px = paddings
    h, wdt = x.shape[1], x.shape[2]
    qy = (h + 2 * py - kh) // sy + 1
    qx = (wdt + 2 * px - kw) // sx + 1
    # Single-phase tap tables: all KH·KW taps, offsets are (ky, kx).
    t_max = kh * kw
    tap_dy = np.repeat(np.arange(kh), kw)[None, :].astype(np.int32)
    tap_dx = np.tile(np.arange(kw), kh)[None, :].astype(np.int32)
    n_taps = np.asarray([t_max], np.int32)
    # Pad input so slice (dy + (qy-1)*sy + 1) stays in bounds.
    need_y = (kh - 1) + (qy - 1) * sy + 1
    need_x = (kw - 1) + (qx - 1) * sx + 1
    pad_y = (py, max(0, need_y - (h + py)))
    pad_x = (px, max(0, need_x - (wdt + px)))
    x_pad = jnp.pad(x, ((0, 0), pad_y, pad_x, (0, 0)))
    w_taps = w.reshape(1, t_max, cin, cout)
    bci, bco = _channel_blocks(cin, cout)
    out_pm = ganax_conv_pallas(x_pad, w_taps, jnp.asarray(n_taps),
                               jnp.asarray(tap_dy), jnp.asarray(tap_dx),
                               out_strides=(sy, sx), qy=qy, qx=qx,
                               block_cin=bci, block_cout=bco,
                               out_dtype=x.dtype, interpret=interpret)
    return out_pm[:, 0]
