"""Collective matmul: ring all-gather overlapped with compute.

The §Roofline collective term for TP training is dominated by blocking
all-gathers/psums around the row/column-parallel matmuls.  The classic TPU
remedy (Wang et al., "Overlap communication with computation") decomposes

    Y = all_gather(X, axis) @ W        (X row-sharded, W local)

into a ring: each step multiplies the resident X shard while `ppermute`
forwards it to the neighbor — the DMA for step i+1 overlaps the MXU work
of step i, hiding up to (P−1)/P of the gather latency.  XLA can do this
automatically in some cases (`--xla_tpu_enable_async_collective_fusion`);
this module provides the explicit shard_map construction for the cases it
misses, plus the matching reduce-scatter form for the backward.

Used as an opt-in building block (`flags`-level wiring is left to the
perf harness; correctness is locked by tests/test_collective_matmul.py).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["ring_allgather_matmul", "ring_matmul_reducescatter"]


def _ring_perm(p: int, direction: int = 1):
    return [(j, (j + direction) % p) for j in range(p)]


def ring_allgather_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """Y = all_gather(x, axis) @ w, gather overlapped with compute.

    x: (m, k) with m sharded over `axis` (m_local per shard);
    w: (k, n) with n sharded over `axis` (local shard used as-is).
    Returns Y: (m_global, n) with n sharded over `axis`.
    """
    p = mesh.shape[axis]

    def local(x_loc, w_loc):
        m_loc = x_loc.shape[0]
        idx = lax.axis_index(axis)
        out = jnp.zeros((m_loc * p, w_loc.shape[1]), x_loc.dtype)

        def body(i, carry):
            x_cur, out = carry
            # x_cur currently holds shard (idx + i) mod p's rows
            y = x_cur @ w_loc
            row = ((idx + i) % p) * m_loc
            out = lax.dynamic_update_slice(out, y, (row, 0))
            # forward to the ring neighbor (overlaps next step's matmul)
            x_nxt = lax.ppermute(x_cur, axis, _ring_perm(p, -1))
            return x_nxt, out

        _, out = lax.fori_loop(0, p, body, (x_loc, out))
        return out

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis))
    return fn(x, w)


def ring_matmul_reducescatter(x, w, mesh: Mesh, axis: str = "model"):
    """Y = reduce_scatter(x @ w, axis) with the scatter overlapped.

    x: (m, k) with k sharded over `axis`; w: (k, n) with k sharded.
    Returns Y: (m, n) with m sharded over `axis` (each shard owns its
    m/P rows of the fully-reduced product) — the backward/row-parallel
    dual of :func:`ring_allgather_matmul`.
    """
    p = mesh.shape[axis]

    def local(x_loc, w_loc):
        m = x_loc.shape[0]
        m_loc = m // p
        idx = lax.axis_index(axis)

        def contrib(b):
            rows = lax.dynamic_slice(x_loc, (b * m_loc, 0),
                                     (m_loc, x_loc.shape[1]))
            return (rows @ w_loc).astype(jnp.float32)

        # The partial-sum buffer for row-block b starts at shard b−1 and
        # travels b, b+1 … — each visited shard adds its contribution —
        # arriving fully summed (minus the destination's own term) at
        # shard b after p−1 hops; each hop's DMA overlaps the next
        # contribution matmul.
        own = contrib(idx)
        if p == 1:
            return own.astype(x_loc.dtype)
        buf = contrib((idx - 1) % p)

        def hop(t, buf):
            buf = lax.ppermute(buf, axis, _ring_perm(p, 1))
            return buf + contrib((idx - 1 - t) % p)

        buf = lax.fori_loop(1, p - 1, hop, buf)
        buf = lax.ppermute(buf, axis, _ring_perm(p, 1))
        return (own + buf).astype(x_loc.dtype)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None))
    return fn(x, w)
