"""Logical-axis → mesh-axis sharding rules.

Model parameters carry *logical* axis names (see ``models/common.PSpec``).
This module maps them onto the physical mesh, with:

* a production default rule set (tensor-parallel over ``model``,
  replication elsewhere);
* divisibility checking with graceful fallback to replication (e.g. hymba's
  25 heads are sharded through the *flattened* ``heads = n_heads·head_dim``
  dimension, which IS divisible — but a 5-way kv dim over 16 shards falls
  back or relies on GSPMD uneven sharding, see ``allow_uneven``);
* ZeRO-1 style extra sharding of optimizer moments over the ``data`` axis;
* per-arch overrides.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["Rules", "DEFAULT_RULES", "param_shardings", "batch_sharding",
           "cache_shardings", "opt_state_shardings", "spec_for_axes"]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical → mesh axis map."""
    table: Mapping[str, str | None] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_TABLE))
    allow_uneven: bool = False   # let GSPMD pad uneven dims instead of
    #                              falling back to replication
    zero1: bool = True           # shard optimizer moments over data axis
    fsdp: bool = False           # additionally shard params over `data`
    #                              on their "embed"-class dim (ZeRO-3 /
    #                              FSDP via GSPMD: per-layer all-gather
    #                              inside the scan, reduce-scatter grads)
    batch_axes: tuple[str, ...] = ("pod", "data")

    def mesh_axis(self, logical: str | None) -> str | None:
        if logical is None:
            return None
        return self.table.get(logical)


FSDP_TABLE: dict[str, str] = {"embed": "data"}


DEFAULT_TABLE: dict[str, str | None] = {
    "vocab": "model",
    "embed": None,
    "mlp": "model",
    "heads": "model",        # flattened n_heads*head_dim
    "kv_heads": "model",     # flattened n_kv*head_dim
    "expert": "model",
    "expert_mlp": None,
    "ssm_inner": "model",
    "ssm_conv_dim": "model",
    "ssm_heads": "model",
    "q_lora": "model",
    "kv_lora": None,
    "conv_in": None,
    "conv_out": "model",
    "layers": None,
}

DEFAULT_RULES = Rules()


def spec_for_axes(axes: tuple[str | None, ...], shape: tuple[int, ...],
                  mesh: Mesh, rules: Rules) -> P:
    """PartitionSpec for one parameter, checking divisibility."""
    entries: list[str | None] = []
    used = set()
    for dim, logical in zip(shape, axes):
        axis = rules.mesh_axis(logical)
        if axis is None or axis not in mesh.shape or axis in used:
            entries.append(None)
            continue
        if dim % mesh.shape[axis] != 0 and not rules.allow_uneven:
            entries.append(None)     # fallback: replicate this dim
            continue
        entries.append(axis)
        used.add(axis)
    if rules.fsdp and len(shape) >= 2:
        for i, (dim, logical) in enumerate(zip(shape, axes)):
            axis = FSDP_TABLE.get(logical or "")
            if (axis and axis in mesh.shape and axis not in used
                    and entries[i] is None
                    and dim % mesh.shape[axis] == 0):
                entries[i] = axis
                used.add(axis)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(mesh: Mesh, axes_tree: Any, shapes_tree: Any,
                    rules: Rules = DEFAULT_RULES) -> Any:
    """NamedSharding pytree for the model parameters.

    ``axes_tree`` — logical axes per leaf (``models.transformer.model_axes``);
    ``shapes_tree`` — matching ShapeDtypeStructs or arrays.
    """
    def one(axes, shaped):
        spec = spec_for_axes(tuple(axes), tuple(shaped.shape), mesh, rules)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def opt_state_shardings(mesh: Mesh, axes_tree: Any, shapes_tree: Any,
                        rules: Rules = DEFAULT_RULES) -> Any:
    """ZeRO-1: moments get the param sharding *plus* data-axis sharding on
    the first divisible unsharded dim."""
    def one(axes, shaped):
        spec = list(spec_for_axes(tuple(axes), tuple(shaped.shape), mesh,
                                  rules))
        spec += [None] * (len(shaped.shape) - len(spec))
        if rules.zero1 and "data" in mesh.shape and "data" not in spec:
            dp = mesh.shape["data"]
            for i, (dim, cur) in enumerate(zip(shaped.shape, spec)):
                if cur is None and dim % dp == 0 and dim >= dp:
                    spec[i] = "data"
                    break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def batch_sharding(mesh: Mesh, ndim: int, rules: Rules = DEFAULT_RULES,
                   batch_dim: int = 0, seq_axis_dim: int | None = None,
                   seq_axis: str | None = None,
                   batch_size: int | None = None) -> NamedSharding:
    """Batch inputs: batch dim over (pod, data); optionally a sequence dim
    over ``seq_axis`` (long-context decode).  If ``batch_size`` is given
    and doesn't divide the full axis product, the largest dividing prefix
    of the batch axes is used (batch=1 long-context → replicated)."""
    entries: list[Any] = [None] * ndim
    axes = tuple(a for a in rules.batch_axes if a in mesh.shape)
    if batch_size is not None:
        while axes and batch_size % int(
                np.prod([mesh.shape[a] for a in axes])) != 0:
            axes = axes[1:]
    entries[batch_dim] = axes if len(axes) > 1 else (axes[0] if axes
                                                     else None)
    if seq_axis_dim is not None and seq_axis in mesh.shape:
        entries[seq_axis_dim] = seq_axis
    while entries and entries[-1] is None:
        entries.pop()
    return NamedSharding(mesh, P(*entries))


def cache_shardings(mesh: Mesh, cache_tree: Any,
                    rules: Rules = DEFAULT_RULES, *,
                    seq_shard: bool = False) -> Any:
    """KV/SSM cache shardings.

    Layout per leaf (leading ``layers`` axis from the segment stacking):
      attn k/v    (L, B, T, Hkv, hd) → (None, batch, [data if seq_shard],
                                        model-if-divisible, None)
      mla ckv     (L, B, T, R)       → (None, batch, [data], None)
      ssm h       (L, B, H, P, N)    → (None, batch, model, None, None)
      ssm conv    (L, B, W-1, C)     → (None, batch, None, model)
    """
    axes = tuple(a for a in rules.batch_axes if a in mesh.shape)
    batch_entry = axes if len(axes) > 1 else (axes[0] if axes else None)

    bs_prod = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    model_div = mesh.shape.get("model", 1)

    def one(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        nd = leaf.ndim
        spec: list[Any] = [None] * nd
        if not seq_shard and leaf.shape[1] % bs_prod == 0:
            spec[1] = batch_entry
        if "k_s" in names or "v_s" in names:       # (L,B,T,H,1) scales
            if seq_shard and "data" in mesh.shape:
                spec[2] = "data"
            if leaf.shape[3] % model_div == 0:
                spec[3] = "model"
        elif "k" in names or "v" in names:         # (L,B,T,H,hd)
            if seq_shard and "data" in mesh.shape:
                spec[2] = "data"
            if leaf.shape[3] % model_div == 0:
                spec[3] = "model"
            elif leaf.shape[4] % model_div == 0:   # shard head_dim instead
                spec[4] = "model"
        elif "ckv" in names or "krope" in names:    # (L,B,T,R)
            if seq_shard and "data" in mesh.shape:
                spec[2] = "data"
        elif "h" in names:                          # (L,B,H,P,N)
            if leaf.shape[2] % model_div == 0:
                spec[2] = "model"
            elif leaf.shape[3] % model_div == 0:
                spec[3] = "model"
        elif "conv" in names:                       # (L,B,W-1,C)
            if leaf.shape[3] % model_div == 0:
                spec[3] = "model"
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
