import os

# Tests see the single real CPU device (the dry-run sets its own
# XLA_FLAGS in subprocesses; see tests/test_distributed.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
