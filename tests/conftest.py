import os
import subprocess
import sys
import textwrap

# Tests see the single real CPU device (multi-device tests run in
# subprocesses via run_forced_devices below; XLA locks the device count
# at first init, so the forcing flag must be set in a fresh process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Prepended to every forced-device snippet: sets the device-forcing
# flag *before* jax initializes, plus the imports every multi-device
# test wants.  The {n} placeholder is filled by run_forced_devices.
MULTIDEVICE_HEADER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = \\
        "--xla_force_host_platform_device_count={n}"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
""")


def run_forced_devices(code: str, n_devices: int = 8, timeout=600):
    """Run ``code`` in a subprocess that sees ``n_devices`` forced host
    CPU devices; the snippet must ``print("PASS")`` on success.

    The shared form of the boilerplate previously duplicated across
    test_distributed / test_collective_matmul / test_hlo: device count
    locks at first jax init, so the main pytest process keeps its
    single real CPU device and every multi-device scenario gets a
    fresh interpreter with ``XLA_FLAGS`` set ahead of the import."""
    full = MULTIDEVICE_HEADER.format(n=int(n_devices)) + \
        textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", full], capture_output=True, text=True,
        timeout=timeout, cwd=ROOT,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    assert "PASS" in out.stdout, (out.stdout[-1500:], out.stderr[-3000:])
    return out
