"""Executable GAN models on the GANAX ops: shapes, dataflow equivalence,
trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gans import GAN_MODELS
from repro.models.gan import (GanConfig, gan_losses, generator_apply,
                              init_gan)


@pytest.mark.parametrize("name", sorted(GAN_MODELS))
def test_generator_shapes_and_losses(name):
    cfg = GanConfig(name=name, channel_scale=0.0625)
    g, d = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))
    img = generator_apply(g, z, cfg)
    nd = len(cfg.layers[0][-1].kernel)
    assert img.ndim == nd + 2 and img.shape[0] == 2
    g_loss, d_loss, fake = gan_losses(g, d, z, jnp.zeros_like(img), cfg)
    assert np.isfinite(float(g_loss)) and np.isfinite(float(d_loss))


def test_dataflow_equivalence():
    """GANAX and zero-insertion dataflows are numerically identical for
    the same weights (the optimization is exact)."""
    for name in ("dcgan", "magan"):
        cfg_g = GanConfig(name=name, channel_scale=0.0625,
                          dataflow="ganax")
        cfg_z = GanConfig(name=name, channel_scale=0.0625,
                          dataflow="zero_insert")
        g, _ = init_gan(cfg_g, jax.random.PRNGKey(0))
        z = jax.random.normal(jax.random.PRNGKey(1), (1, cfg_g.z_dim))
        a = generator_apply(g, z, cfg_g)
        b = generator_apply(g, z, cfg_z)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_pallas_backed_generator_matches():
    cfg = GanConfig(name="dcgan", channel_scale=0.03125, use_pallas=True)
    cfg_ref = GanConfig(name="dcgan", channel_scale=0.03125,
                        use_pallas=False)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.z_dim))
    a = generator_apply(g, z, cfg)
    b = generator_apply(g, z, cfg_ref)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3,
                               rtol=2e-3)


def test_gan_one_train_step_improves_discriminator():
    cfg = GanConfig(name="dcgan", channel_scale=0.0625)
    g, d = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.z_dim))
    real = jax.random.normal(jax.random.PRNGKey(2), (4, 64, 64, 3))

    def d_loss_fn(d):
        _, dl, _ = gan_losses(g, d, z, real, cfg)
        return dl

    l0 = float(d_loss_fn(d))
    grads = jax.grad(d_loss_fn)(d)
    d2 = jax.tree.map(lambda p, gr: p - 0.05 * gr, d, grads)
    l1 = float(d_loss_fn(d2))
    assert l1 < l0
