"""Ring collective-matmul (comm/compute overlap) vs dense references."""

import pytest
from conftest import run_forced_devices


@pytest.mark.slow
def test_ring_matmuls_match_dense():
    run_forced_devices("""
        from repro.sharding.collective_matmul import (
            ring_allgather_matmul, ring_matmul_reducescatter)
        for shape, axes, ax in [((2, 4), ("data", "model"), "model"),
                                ((8,), ("model",), "model")]:
            mesh = jax.make_mesh(shape, axes)
            p = mesh.shape[ax]
            rng = np.random.default_rng(0)
            m, k, n = 8 * p, 32, 16 * p
            x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
            w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
            with mesh:
                y = jax.jit(lambda x, w: ring_allgather_matmul(
                    x, w, mesh, ax))(x, w)
            np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                       atol=1e-4, rtol=1e-4)
            # reduce-scatter form: k sharded
            k2 = 16 * p
            x2 = jnp.asarray(rng.normal(size=(m, k2)), jnp.float32)
            w2 = jnp.asarray(rng.normal(size=(k2, n)), jnp.float32)
            with mesh:
                y2 = jax.jit(lambda x, w: ring_matmul_reducescatter(
                    x, w, mesh, ax))(x2, w2)
            np.testing.assert_allclose(np.asarray(y2),
                                       np.asarray(x2 @ w2),
                                       atol=1e-4, rtol=1e-4)
        print("PASS")
    """)
