"""CI docs smoke runner (`tools/docs_smoke.py`): fenced-block
extraction (info strings, skip marker), shared per-file namespace
execution, and failure attribution to doc file + line."""

import sys
import textwrap
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from tools import docs_smoke  # noqa: E402

MD = textwrap.dedent("""\
    # Title

    ```python
    x = 1
    ```

    ```bash
    echo not-python
    ```

    <!-- docs-smoke: skip -->
    ```python
    raise RuntimeError("must not run")
    ```

    ```py
    y = x + 1
    ```
""")


def test_extract_blocks_info_strings_and_skip():
    blocks = docs_smoke.extract_blocks(MD)
    assert [code for _, code in blocks] == ["x = 1", "y = x + 1"]
    # 1-indexed first code line of each block
    assert [line for line, _ in blocks] == [4, 17]


def test_run_file_shares_namespace_across_blocks(tmp_path):
    p = tmp_path / "doc.md"
    p.write_text(MD)
    assert docs_smoke.run_file(p) == 2   # skipped block didn't run


def test_run_file_failure_names_doc_and_line(tmp_path):
    p = tmp_path / "bad.md"
    p.write_text("```python\nboom\n```\n")
    with pytest.raises(NameError) as exc:
        docs_smoke.run_file(p)
    tb = exc.traceback[-1]
    assert str(tb.path).endswith("bad.md:2")


def test_default_files_cover_readme_and_docs():
    files = [f.name for f in docs_smoke.default_files()]
    assert "README.md" in files
    assert "ARCHITECTURE.md" in files and "serving.md" in files


def test_main_runs_and_reports(tmp_path, capsys):
    good = tmp_path / "good.md"
    good.write_text("```python\nassert 1 + 1 == 2\n```\n")
    assert docs_smoke.main([str(good)]) == 0
    assert "1 block(s)" in capsys.readouterr().out
    bad = tmp_path / "bad.md"
    bad.write_text("```python\nraise ValueError('x')\n```\n")
    assert docs_smoke.main([str(good), str(bad)]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "bad.md" in out
