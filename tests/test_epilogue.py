"""Fused bias+activation epilogues in the unified dataflow dispatch.

Pins the PR-4 contract: (1) fused and unfused formulations agree —
forward and ``jax.grad`` — on every backend × activation × stride, for
2-D and volumetric ops; (2) the Table-I models issue **zero**
out-of-kernel ``+ b`` / activation ops on the fused kernel path (the
epilogue lives inside the custom-VJP-wrapped kernel call); (3) the
legacy ``GanConfig`` flags warn, ``backend=`` does not.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gans import GAN_MODELS
from repro.core.dataflow import (ACTIVATIONS, DataflowPolicy, Epilogue,
                                 conv, tconv)
from repro.models.gan import (GanConfig, discriminator_apply,
                              discriminator_epilogues, generator_apply,
                              generator_epilogues, init_gan)

BACKENDS = ["zero-insert", "polyphase", "pallas-interpret", "pallas"]

# (x_spatial, kernel, cin, cout) per stride — tiny shapes: the sweep
# below multiplies out to backends × activations × strides × {tconv,
# conv} × {2-D, 3-D}, each with a gradient check.
SPATIAL_2D = {1: ((5, 5), (3, 3)), 2: ((4, 4), (4, 4)),
              3: ((3, 3), (3, 3))}
SPATIAL_3D = {1: ((3, 3, 3), (2, 2, 2)), 2: ((2, 3, 2), (3, 3, 3)),
              3: ((2, 2, 2), (2, 2, 2))}   # kernel < stride: empty phases


def _case(nd, stride, cin=2, cout=3, seed=0):
    sp, k = (SPATIAL_2D if nd == 2 else SPATIAL_3D)[stride]
    rng = np.random.default_rng(seed + 31 * stride + 7 * nd)
    x = jnp.asarray(rng.normal(size=(1, *sp, cin)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(*k, cin, cout)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(cout,)), jnp.float32)
    s = (stride,) * nd
    p = tuple(min(1, kk - 1) for kk in k)
    return x, w, b, s, p


def _unfused(op, x, w, b, s, p, policy, ep):
    """The reference formulation: bare op, then the epilogue as
    out-of-op XLA post-ops."""
    return ep.apply(op(x, w, s, p, policy=policy), b if ep.bias else None)


def _assert_fwd_and_grad_parity(op, x, w, b, s, p, policy, ep, tol=1e-4):
    fused = op(x, w, s, p, policy=policy, bias=b if ep.bias else None,
               epilogue=ep)
    ref = _unfused(op, x, w, b, s, p,
                   DataflowPolicy(backend="zero-insert"), ep)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=tol, rtol=tol)
    cot = jnp.asarray(np.random.default_rng(3).normal(size=ref.shape),
                      jnp.float32)
    argnums = (0, 1, 2) if ep.bias else (0, 1)

    def fused_loss(x, w, b):
        return jnp.sum(op(x, w, s, p, policy=policy,
                          bias=b if ep.bias else None, epilogue=ep) * cot)

    def ref_loss(x, w, b):
        return jnp.sum(_unfused(
            op, x, w, b, s, p,
            DataflowPolicy(backend="zero-insert"), ep) * cot)

    got = jax.grad(fused_loss, argnums)(x, w, b)
    want = jax.grad(ref_loss, argnums)(x, w, b)
    for g_, r_, name in zip(got, want, ("dx", "dw", "db")):
        np.testing.assert_allclose(np.asarray(g_), np.asarray(r_),
                                   atol=tol, rtol=tol, err_msg=name)


@pytest.mark.parametrize("activation", ACTIVATIONS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_parity_2d(backend, activation):
    """Fused == unfused (forward and grad) for 2-D tconv and conv on
    every backend, strides {1, 2, 3}."""
    policy = DataflowPolicy(backend=backend)
    ep = Epilogue(bias=True, activation=activation)
    for stride in (1, 2, 3):
        x, w, b, s, p = _case(2, stride)
        _assert_fwd_and_grad_parity(tconv, x, w, b, s, p, policy, ep)
        _assert_fwd_and_grad_parity(conv, x, w, b, s, p, policy, ep)


@pytest.mark.parametrize("activation", ACTIVATIONS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_parity_3d(backend, activation):
    """Volumetric twin of the 2-D sweep (the 3D-GAN path), including the
    kernel<stride geometry whose empty phases are pure-epilogue
    outputs ``act(0 + b)``."""
    policy = DataflowPolicy(backend=backend)
    ep = Epilogue(bias=True, activation=activation)
    for stride in (1, 2, 3):
        x, w, b, s, p = _case(3, stride)
        _assert_fwd_and_grad_parity(tconv, x, w, b, s, p, policy, ep)
        _assert_fwd_and_grad_parity(conv, x, w, b, s, p, policy, ep)


def test_activation_only_epilogue_no_bias():
    """bias=False epilogues thread a None bias through the fused custom
    VJP (the cotangent structure must match)."""
    policy = DataflowPolicy(backend="pallas-interpret")
    ep = Epilogue(activation="leaky_relu", leaky_slope=0.1)
    x, w, b, s, p = _case(2, 2)
    _assert_fwd_and_grad_parity(tconv, x, w, b, s, p, policy, ep)


def test_epilogue_validation():
    with pytest.raises(ValueError, match="activation"):
        Epilogue(activation="gelu")
    # grad_from_output recovers the leaky derivative from the output's
    # sign, which needs a sign-preserving (non-negative) slope
    with pytest.raises(ValueError, match="leaky_slope"):
        Epilogue(activation="leaky_relu", leaky_slope=-0.1)
    x, w, b, s, p = _case(2, 2)
    with pytest.raises(ValueError, match="bias"):
        tconv(x, w, s, p, epilogue=Epilogue(bias=True))   # missing array
    with pytest.raises(ValueError, match="bias"):
        tconv(x, w, s, p, bias=b, epilogue=Epilogue(bias=False))
    with pytest.raises(ValueError, match="cout"):
        tconv(x, w, s, p, bias=jnp.zeros((7,)),
              epilogue=Epilogue(bias=True))
    # a bare bias= array means a fused bias add — at the dispatch layer
    # and at the ops-layer kernel entry points alike
    out = tconv(x, w, s, p, bias=b)
    ref = tconv(x, w, s, p) + b
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    from repro.kernels.ops import ganax_conv_transpose
    out = ganax_conv_transpose(x, w, s, p, interpret=True, bias=b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_leaky_slope_canonicalized():
    """Specs computing the same function hash equal (plan-key dedup):
    the slope only survives for leaky_relu."""
    assert Epilogue(activation="relu", leaky_slope=0.7) == \
        Epilogue(activation="relu")
    assert Epilogue(activation="leaky_relu", leaky_slope=0.3) != \
        Epilogue(activation="leaky_relu")
    assert Epilogue().is_identity
    assert not Epilogue(bias=True).is_identity


# ---------------------------------------------------------------------------
# Table-I acceptance: zero out-of-kernel epilogue ops + model-level parity.
# ---------------------------------------------------------------------------

def _top_level_prims(fn, *args) -> list[str]:
    return [e.primitive.name
            for e in jax.make_jaxpr(fn)(*args).jaxpr.eqns]


@pytest.mark.parametrize("name", sorted(GAN_MODELS))
def test_no_out_of_kernel_epilogue_ops(name):
    """On the fused kernel path every conv layer traces to a single
    custom-VJP call: no top-level ``add`` (bias) and no top-level
    tanh/max/select_n (activations) besides the generator's z-projection
    MLP, for every Table-I model."""
    cfg = GanConfig(name=name, channel_scale=0.03125,
                    backend="pallas-interpret")
    g, d = init_gan(cfg, jax.random.PRNGKey(0))
    g_layers, d_layers = cfg.layers
    z = jnp.zeros((1, cfg.z_dim))

    prims = _top_level_prims(lambda g, z: generator_apply(g, z, cfg), g, z)
    activationish = {"tanh", "max", "select_n", "logistic"}
    assert not activationish & set(prims), prims
    assert prims.count("add") == 1, prims            # the projection bias
    assert prims.count("custom_jvp_call") == 1       # the projection relu
    assert prims.count("custom_vjp_call_jaxpr") == len(g_layers)

    img_sp = tuple(d_layers[0].in_spatial)
    img = jnp.zeros((1, *img_sp, d_layers[0].cin))
    prims = _top_level_prims(
        lambda d, img: discriminator_apply(d, img, cfg), d, img)
    assert "add" not in prims, prims
    assert not activationish & set(prims), prims
    assert prims.count("custom_vjp_call_jaxpr") == len(d_layers)


@pytest.mark.parametrize("name", sorted(GAN_MODELS))
def test_model_fused_matches_unfused(name):
    """Model-level parity for every Table-I model: the fused generator
    and discriminator match a manually unfused reference (bare ops +
    post-ops) to fp32 tolerance."""
    cfg = GanConfig(name=name, channel_scale=0.0625, backend="polyphase")
    g, d = init_gan(cfg, jax.random.PRNGKey(0))
    g_layers, d_layers = cfg.layers
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))

    from repro.core.dataflow import conv as df_conv
    from repro.core.dataflow import tconv as df_tconv

    def unfused_generator(params):
        policy = cfg.policy
        first = g_layers[0]
        x = z @ params["proj_w"] + params["proj_b"]
        x = x.reshape((z.shape[0],) + tuple(first.in_spatial)
                      + (first.cin,))
        x = jax.nn.relu(x)
        for i, (l, ep) in enumerate(zip(g_layers,
                                        generator_epilogues(g_layers))):
            op = df_tconv if l.transposed else df_conv
            x = ep.apply(op(x, params[f"t{i}_w"], l.strides, l.paddings,
                            policy=policy), params[f"t{i}_b"])
        return x

    fused = generator_apply(g, z, cfg)
    np.testing.assert_allclose(np.asarray(fused),
                               np.asarray(unfused_generator(g)),
                               atol=2e-4, rtol=2e-4)

    def unfused_discriminator(params, img):
        policy = cfg.policy
        x = img
        for i, (l, ep) in enumerate(zip(
                d_layers, discriminator_epilogues(d_layers))):
            x = ep.apply(df_conv(x, params[f"c{i}_w"], l.strides,
                                 l.paddings, policy=policy),
                         params[f"c{i}_b"])
        return x.reshape(img.shape[0], -1).mean(axis=-1)

    img = fused
    np.testing.assert_allclose(
        np.asarray(discriminator_apply(d, img, cfg)),
        np.asarray(unfused_discriminator(d, img)), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_generator_fused_grad_parity_across_backends(backend):
    """Fused end-to-end generator gradients agree across every backend
    (the kernel backends differentiate through the fused custom VJP)."""
    cfg = GanConfig(name="dcgan", channel_scale=0.03125, backend=backend)
    cfg_ref = GanConfig(name="dcgan", channel_scale=0.03125,
                        backend="zero-insert")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.z_dim))

    def loss(g, cfg):
        return jnp.sum(generator_apply(g, z, cfg) ** 2)

    got = jax.grad(loss)(g, cfg)
    want = jax.grad(loss)(g, cfg_ref)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# Legacy-flag deprecation path.
# ---------------------------------------------------------------------------

def test_legacy_gan_config_flags_warn():
    with pytest.warns(DeprecationWarning, match="backend"):
        GanConfig(name="dcgan", use_pallas=True).policy
    with pytest.warns(DeprecationWarning, match="deprecated"):
        DataflowPolicy.from_legacy(dataflow="zero_insert")


def test_supported_knobs_do_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert GanConfig(name="dcgan").policy.backend == "polyphase"
        assert GanConfig(name="dcgan", backend="auto").policy.backend \
            == "auto"
