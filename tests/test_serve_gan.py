"""GAN serving engine: program-backed execution, remainder buffering,
determinism."""

import numpy as np
import jax

from repro.models.gan import GanConfig, init_gan
from repro.serve.gan import GanServer


def _server(batch_size=2, seed=0):
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    return GanServer(cfg, g, batch_size=batch_size, seed=seed)


def test_generate_shapes_and_batching():
    srv = _server(batch_size=2)
    imgs = srv.generate(3)
    assert imgs.shape == (3, 64, 64, 3)
    assert srv.batches_served == 2  # 3 images → two 2-batches
    assert srv.samples_buffered == 1  # tail sample carried, not dropped


def test_sample_accounting_with_remainder_buffer():
    """Tail samples beyond n are real generator compute: they are
    buffered for the next call, never discarded, and the counters
    account for every sample produced."""
    srv = _server(batch_size=4)

    srv.generate(3)              # one batch: 3 served, 1 buffered
    assert (srv.samples_served, srv.samples_buffered,
            srv.samples_discarded) == (3, 1, 0)
    assert srv.batches_served == 1

    srv.generate(8)              # 1 from buffer + two batches, 1 left
    assert (srv.samples_served, srv.samples_buffered,
            srv.samples_discarded) == (11, 1, 0)
    assert srv.batches_served == 3

    srv.generate(5)              # 1 from buffer + one batch, exact
    assert (srv.samples_served, srv.samples_buffered,
            srv.samples_discarded) == (16, 0, 0)
    assert srv.batches_served == 4

    # invariant: every produced sample is served, buffered, or discarded
    assert srv.samples_served + srv.samples_buffered + \
        srv.samples_discarded == srv.batches_served * 4
    r = repr(srv)
    assert "served=16" in r and "buffered=0" in r and "discarded=0" in r


def test_buffered_samples_serve_in_order():
    """The carried remainder is exactly the tail of the last batch: two
    servers with the same seed produce the same stream regardless of the
    call pattern chunking."""
    a = _server(batch_size=4, seed=5)
    b = _server(batch_size=4, seed=5)
    chunked = np.concatenate([a.generate(3), a.generate(3),
                              a.generate(2)])
    whole = b.generate(8)
    np.testing.assert_array_equal(chunked, whole)
    assert a.batches_served == b.batches_served == 2


def test_repr_exposes_resolved_policy():
    srv = _server()
    # CPU host, pinned-by-legacy-config policy → polyphase
    assert "policy=polyphase" in repr(srv)
    # the frozen program is inspectable layer by layer
    desc = srv.describe()
    assert "program dcgan/generator" in desc
    assert desc.count("-> polyphase") == 4


def test_auto_policy_builds_measured_program_on_construction():
    """A backend='auto' server resolves (measuring) a plan for every
    generator layer at program build — before the first jit trace — and
    a warm planner means a second server measures nothing."""
    from repro.tune import Planner, set_planner

    planner = set_planner(Planner(repeats=1))
    try:
        cfg = GanConfig(name="dcgan", channel_scale=0.03125,
                        backend="auto")
        g, _ = init_gan(cfg, jax.random.PRNGKey(0))
        srv = GanServer(cfg, g, batch_size=2)
        g_layers, _ = cfg.layers
        assert len(srv.program.spec.layers) == len(g_layers)
        assert all(le.source == "tuned"
                   for le in srv.program.spec.layers)
        assert planner.measurements > 0
        assert repr(srv).startswith("GanServer(model='dcgan'")
        assert "auto(" in repr(srv)
        imgs = srv.generate(2)
        assert imgs.shape == (2, 64, 64, 3)

        # a second server on the warm planner measures nothing
        meas = planner.measurements
        srv2 = GanServer(cfg, g, batch_size=2)
        assert planner.measurements == meas
        assert len(srv2.program.spec.layers) == len(g_layers)
    finally:
        set_planner(None)


def test_auto_matches_pinned_numerics():
    """Acceptance: the auto-policy server's frozen program serves
    bit-identical images to the concrete backend its plans name."""
    from repro.models.gan import generator_epilogues
    from repro.tune import Plan, Planner, set_planner
    from repro.tune.zoo import layer_plan_keys

    cfg = GanConfig(name="dcgan", channel_scale=0.03125, backend="auto")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    planner = set_planner(Planner())
    try:
        g_layers, _ = cfg.layers
        for _, key in layer_plan_keys(
                g_layers, batch=2,
                epilogues=generator_epilogues(g_layers)):
            planner.put(key, Plan(backend="zero-insert"))
        srv = GanServer(cfg, g, batch_size=2, seed=3)
        assert planner.measurements == 0   # plans were warm
        auto_imgs = srv.generate(2)
    finally:
        set_planner(None)
    cfg_z = GanConfig(name="dcgan", channel_scale=0.03125,
                      backend="zero-insert")
    pinned_imgs = GanServer(cfg_z, g, batch_size=2, seed=3).generate(2)
    np.testing.assert_array_equal(auto_imgs, pinned_imgs)


def test_exported_program_serves():
    """ProgramSpec JSON → Program → GanServer(program=...): the
    ship-a-tuned-program flow."""
    from repro.program import Program, ProgramSpec

    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    ref = GanServer(cfg, g, batch_size=2, seed=3)
    spec = ProgramSpec.from_json(ref.program.spec.to_json())
    srv = GanServer(cfg, g, batch_size=2, seed=3,
                    program=Program(spec, differentiable=False))
    np.testing.assert_array_equal(srv.generate(3), ref.generate(3))


def test_generate_deterministic_per_seed():
    a = _server(seed=7).generate(2)
    b = _server(seed=7).generate(2)
    c = _server(seed=8).generate(2)
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0
