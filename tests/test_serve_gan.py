"""GAN serving engine: fixed-batch jitting, tail slicing, determinism."""

import numpy as np
import jax

from repro.models.gan import GanConfig, init_gan
from repro.serve.gan import GanServer


def _server(batch_size=2, seed=0):
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    return GanServer(cfg, g, batch_size=batch_size, seed=seed)


def test_generate_shapes_and_batching():
    srv = _server(batch_size=2)
    imgs = srv.generate(3)
    assert imgs.shape == (3, 64, 64, 3)
    assert srv.batches_served == 2  # 3 images → two 2-batches, tail sliced


def test_generate_deterministic_per_seed():
    a = _server(seed=7).generate(2)
    b = _server(seed=7).generate(2)
    c = _server(seed=8).generate(2)
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0
