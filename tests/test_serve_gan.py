"""GAN serving engine: fixed-batch jitting, tail slicing, determinism."""

import numpy as np
import jax

from repro.models.gan import GanConfig, init_gan
from repro.serve.gan import GanServer


def _server(batch_size=2, seed=0):
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    return GanServer(cfg, g, batch_size=batch_size, seed=seed)


def test_generate_shapes_and_batching():
    srv = _server(batch_size=2)
    imgs = srv.generate(3)
    assert imgs.shape == (3, 64, 64, 3)
    assert srv.batches_served == 2  # 3 images → two 2-batches, tail sliced


def test_sample_accounting():
    """Discarded tail samples are real generator compute; the counters
    must account for every sample produced."""
    srv = _server(batch_size=4)
    srv.generate(3)              # one batch: 3 served, 1 discarded
    assert (srv.samples_served, srv.samples_discarded) == (3, 1)
    srv.generate(8)              # two full batches: no discards
    assert (srv.samples_served, srv.samples_discarded) == (11, 1)
    srv.generate(5)              # 4 + 1 of 4 → 3 discarded
    assert (srv.samples_served, srv.samples_discarded) == (16, 4)
    assert srv.batches_served == 5
    r = repr(srv)
    assert "served=16" in r and "discarded=4" in r


def test_repr_exposes_resolved_policy():
    srv = _server()
    # CPU host, pinned-by-legacy-config policy → polyphase
    assert "policy=polyphase" in repr(srv)


def test_auto_policy_warms_plans_on_construction():
    """A backend='auto' server resolves a plan for every generator layer
    before its first jit trace, and a warm planner means the warmup does
    zero measurements."""
    from repro.tune import Planner, set_planner

    planner = set_planner(Planner(repeats=1))
    try:
        cfg = GanConfig(name="dcgan", channel_scale=0.03125,
                        backend="auto")
        g, _ = init_gan(cfg, jax.random.PRNGKey(0))
        srv = GanServer(cfg, g, batch_size=2)
        g_layers, _ = cfg.layers
        assert len(srv.plans) == len(g_layers)
        assert srv.plans and planner.measurements > 0
        assert repr(srv).startswith("GanServer(model='dcgan'")
        assert "auto(" in repr(srv)
        imgs = srv.generate(2)
        assert imgs.shape == (2, 64, 64, 3)

        # a second server on the warm planner measures nothing
        meas = planner.measurements
        srv2 = GanServer(cfg, g, batch_size=2)
        assert planner.measurements == meas
        assert len(srv2.plans) == len(g_layers)
    finally:
        set_planner(None)


def test_auto_matches_pinned_numerics():
    """Acceptance: the auto policy server serves bit-identical images to
    the concrete backend its plans name."""
    from repro.tune import Plan, Planner, set_planner
    from repro.tune.zoo import layer_plan_keys

    cfg = GanConfig(name="dcgan", channel_scale=0.03125, backend="auto")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    planner = set_planner(Planner())
    try:
        g_layers, _ = cfg.layers
        for _, key in layer_plan_keys(g_layers, batch=2):
            planner.put(key, Plan(backend="zero-insert"))
        auto_imgs = GanServer(cfg, g, batch_size=2, seed=3).generate(2)
    finally:
        set_planner(None)
    cfg_z = GanConfig(name="dcgan", channel_scale=0.03125,
                      backend="zero-insert")
    pinned_imgs = GanServer(cfg_z, g, batch_size=2, seed=3).generate(2)
    np.testing.assert_allclose(auto_imgs, pinned_imgs, atol=1e-5,
                               rtol=1e-5)


def test_generate_deterministic_per_seed():
    a = _server(seed=7).generate(2)
    b = _server(seed=7).generate(2)
    c = _server(seed=8).generate(2)
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0
