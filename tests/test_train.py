"""Training substrate: optimizer, grad accumulation, checkpointing,
fault-tolerant loop, gradient compression."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import MemmapTokens, Prefetcher, SyntheticLM, \
    make_batch_fn
from repro.models import transformer as tr
from repro.train import checkpoint as ckpt
from repro.train.compress import (dequantize_int8, make_int8_grad_transform,
                                  quantize_int8)
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, \
    cosine_schedule
from repro.train.train_state import init_train_state, make_train_step

TINY = dataclasses.replace(
    get_config("gemma-7b"), n_layers=2, d_model=32, d_ff=64, vocab=64,
    n_heads=2, n_kv_heads=2, head_dim=16, tie_embeddings=False)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(peak_lr=0.3, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, grad_clip=0.0)
    lr = cosine_schedule(cfg)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(params, g, state, cfg, lr)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.asarray(0))) < 0.11
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_grad_accum_equivalence():
    """accum=4 over 4 microbatches == accum=1 over the concatenated batch
    (same loss gradient, same update)."""
    key = jax.random.PRNGKey(0)
    opt = AdamWConfig(peak_lr=1e-2, warmup_steps=1, grad_clip=0.0,
                      weight_decay=0.0)
    flags = tr.RunFlags(remat=False)
    toks = jax.random.randint(key, (8, 16), 0, TINY.vocab)

    s1 = init_train_state(TINY, key)
    step1 = make_train_step(TINY, opt, flags, grad_accum=1)
    s1b, m1 = step1(s1, {"tokens": toks})

    s4 = init_train_state(TINY, key)
    step4 = make_train_step(TINY, opt, flags, grad_accum=4)
    s4b, m4 = step4(s4, {"tokens": toks.reshape(4, 2, 16)})

    for a, b in zip(jax.tree.leaves(s1b["params"]),
                    jax.tree.leaves(s4b["params"])):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        mismatched = np.abs(a - b) > (2e-5 + 2e-5 * np.abs(b))
        # float reassociation can flip the sign of a ~zero gradient,
        # which Adam turns into a ±lr step on that one element — allow a
        # vanishing fraction of such knife-edge elements
        assert mismatched.mean() < 2e-3, mismatched.mean()


def test_training_reduces_loss():
    key = jax.random.PRNGKey(0)
    opt = AdamWConfig(peak_lr=5e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(TINY, opt, tr.RunFlags(remat=False)))
    state = init_train_state(TINY, key)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, TINY.vocab)}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)  # memorize one batch
        losses.append(float(m["total_loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_checkpoint_roundtrip(tmp_path):
    key = jax.random.PRNGKey(1)
    state = init_train_state(TINY, key)
    d = str(tmp_path / "ck")
    ckpt.save(state, d, 7)
    assert ckpt.latest_step(d) == 7
    tmpl = jax.tree.map(lambda a: jnp.zeros_like(a), state)
    restored = ckpt.restore(tmpl, d, 7)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    state = {"x": jnp.arange(4)}
    ckpt.save(state, d, 1)
    # a stale .tmp dir from a crashed save must not break the next save
    os.makedirs(os.path.join(d, "step_00000002.tmp", "arrays"),
                exist_ok=True)
    ckpt.save(state, d, 2)
    assert ckpt.all_steps(d) == [1, 2]


def test_loop_failure_injection_recovers(tmp_path):
    """Deterministic data + checkpoint/replay ⇒ a crashed-and-restarted run
    converges to the same state as an uninterrupted one."""
    key = jax.random.PRNGKey(0)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    flags = tr.RunFlags(remat=False)
    step = jax.jit(make_train_step(TINY, opt, flags))
    src = SyntheticLM(TINY, batch=2, seq_len=16, seed=3)
    batch_fn = make_batch_fn(src)

    def run(inject):
        state = init_train_state(TINY, key)
        loop = TrainLoop(
            LoopConfig(total_steps=12, ckpt_dir=str(tmp_path / "ck"),
                       ckpt_every=4, async_ckpt=False, log_every=100),
            step, batch_fn, state,
            failure_injector=inject, log_fn=lambda s: None)
        return loop.run(), loop

    fired = []

    def inject(step_no):
        if step_no == 7 and not fired:
            fired.append(True)
            return True
        return False

    import shutil
    state_f, loop_f = run(inject)
    shutil.rmtree(tmp_path / "ck")
    state_c, loop_c = run(None)
    assert loop_f.restarts == 1
    for a, b in zip(jax.tree.leaves(state_f["params"]),
                    jax.tree.leaves(state_c["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    tmpl = {"w": jnp.zeros((64,))}
    transform, init_err = make_int8_grad_transform(tmpl)
    err = init_err()
    # with error feedback, the *accumulated* quantized gradient tracks the
    # accumulated true gradient
    total_true = np.zeros(64)
    total_q = np.zeros(64)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=64) * 0.01, jnp.float32)}
        q, err = transform(g, err)
        total_true += np.asarray(g["w"])
        total_q += np.asarray(q["w"])
    drift = np.abs(total_q - total_true).max()
    assert drift < 5e-3, drift


def test_quantize_int8_bounds():
    x = jnp.asarray([-1.0, 0.0, 0.5, 1.0])
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s)),
                               np.asarray(x), atol=1.0 / 127)


def test_synthetic_data_deterministic():
    src = SyntheticLM(TINY, batch=2, seq_len=8, seed=5)
    a = src(3)["tokens"]
    b = src(3)["tokens"]
    c = src(4)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()
    assert a.max() < TINY.vocab


def test_memmap_tokens(tmp_path):
    path = str(tmp_path / "toks.bin")
    np.arange(10000, dtype=np.uint16).tofile(path)
    src = MemmapTokens(path, TINY, batch=2, seq_len=16)
    b0 = src(0)["tokens"]
    b1 = src(1)["tokens"]
    assert b0.shape == (2, 16)
    assert (b0 != b1).any()
    np.testing.assert_array_equal(src(0)["tokens"], b0)  # deterministic


def test_prefetcher():
    src = SyntheticLM(TINY, batch=1, seq_len=8, seed=0)
    pf = Prefetcher(make_batch_fn(src), start_step=0, depth=2)
    steps = [pf.get()[0] for _ in range(4)]
    pf.stop()
    assert steps == [0, 1, 2, 3]


def test_straggler_watchdog():
    import time
    state = {"x": jnp.zeros(())}

    def slow_step(state, batch):
        if batch["i"] == 5:
            time.sleep(0.25)
        else:
            time.sleep(0.01)
        return state, {"loss": jnp.zeros(())}

    loop = TrainLoop(
        LoopConfig(total_steps=8, ckpt_dir="/tmp/_nock", ckpt_every=1000,
                   straggler_factor=3.0, log_every=100),
        slow_step, lambda i: {"i": i}, state, log_fn=lambda s: None)
    loop.run()
    assert 5 in loop.straggler_events
