"""Model zoo: per-arch reduced-config smoke tests + numerics.

Every assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes and
finiteness; causal archs additionally run one decode step, and the
prefill→decode handoff is validated against the full-sequence forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, cell_supported, get_config, \
    list_configs
from repro.models import transformer as tr
from repro.models.attention import (decode_attention, flash_attention,
                                    naive_attention, swa_attention)

ARCHS = list_configs()


def tiny(cfg, **over):
    base = dict(n_layers=4, d_model=64, d_ff=128, vocab=97)
    if cfg.n_heads:
        base.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
                    head_dim=16)
    if cfg.mla:
        base.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    if cfg.moe:
        base.update(n_experts=4, top_k=min(cfg.top_k, 2), expert_d_ff=32,
                    capacity_factor=4.0)
    if cfg.ssm:
        base.update(ssm_state=8, ssm_head_dim=8)
    if cfg.local_window:
        base.update(local_window=8)
    if cfg.global_layers:
        base.update(global_layers=(0, 3))
    if cfg.local_global_pattern[0]:
        base.update(local_global_pattern=(2, 1))
    if cfg.img_tokens:
        base.update(img_tokens=8)
    base.update(over)
    return dataclasses.replace(cfg, **base)


def make_batch(cfg, b=2, s=32):
    if cfg.family == "encoder":
        return {"features": jnp.ones((b, s, cfg.frontend_dim),
                                     jnp.float32),
                "labels": jnp.zeros((b, s), jnp.int32),
                "label_mask": jnp.ones((b, s), jnp.float32)}
    batch = {"tokens": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.ones((b, cfg.img_tokens,
                                        cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = tiny(get_config(arch))
    params = tr.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, _, _ = tr.forward(params, batch, cfg, mode="train")
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = tr.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: tr.loss_fn(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).supports_decode])
def test_smoke_decode(arch):
    cfg = tiny(get_config(arch))
    params = tr.init(cfg, jax.random.PRNGKey(0))
    cache = tr.init_cache(cfg, 2, 16)
    logits, cache2 = tr.decode_step(
        params, cache, jnp.ones((2, 1), jnp.int32),
        jnp.zeros((2,), jnp.int32), cfg)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).supports_decode])
def test_prefill_decode_consistency(arch):
    cfg = tiny(get_config(arch), dtype="float32")
    params = tr.init(cfg, jax.random.PRNGKey(0))
    B, S, EXTRA = 2, 24, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + EXTRA), 0,
                              cfg.vocab)
    ref, _, _ = tr.forward(params, {"tokens": toks}, cfg, mode="train")
    _, cache, _ = tr.forward(params, {"tokens": toks[:, :S]}, cfg,
                             mode="prefill")
    maxlen = S + EXTRA + 1
    cache = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, maxlen - a.shape[2])]
                          + [(0, 0)] * (a.ndim - 3))
        if a.ndim >= 3 and a.shape[2] == S else a, cache)
    lengths = jnp.full((B,), S, jnp.int32)
    for t in range(EXTRA):
        logits, cache = tr.decode_step(params, cache,
                                       toks[:, S + t:S + t + 1],
                                       lengths, cfg)
        err = float(jnp.max(jnp.abs(logits - ref[:, S + t])))
        assert err < 2e-3, (arch, t, err)
        lengths = lengths + 1


def test_attention_variants_agree():
    rng = np.random.default_rng(0)
    B, S, Hq, Hk, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hk, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = naive_attention(q, k, v, pos, pos, causal=True)
    fl = flash_attention(q, k, v, pos, pos, causal=True, block_k=16)
    np.testing.assert_allclose(np.asarray(fl), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
    for w in (8, 16, 17):
        ref_w = naive_attention(q, k, v, pos, pos, causal=True, window=w)
        sw = swa_attention(q, k, v, pos, pos, window=w)
        np.testing.assert_allclose(np.asarray(sw), np.asarray(ref_w),
                                   atol=2e-5, rtol=2e-5)


def test_decode_attention_respects_lengths():
    rng = np.random.default_rng(1)
    B, T, H, hd = 2, 16, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    out_a = decode_attention(q, kc, vc, jnp.asarray([3, 7]))
    # corrupting cache entries beyond `lengths` must not change the output
    kc2 = kc.at[:, 10:].set(1e3)
    vc2 = vc.at[:, 10:].set(-1e3)
    out_b = decode_attention(q, kc2, vc2, jnp.asarray([3, 7]))
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b))


def test_moe_dispatch_properties():
    from repro.models import moe as moe_mod
    cfg = tiny(get_config("olmoe-1b-7b"))
    params = jax.tree.map(
        lambda s: jnp.asarray(
            np.random.default_rng(0).normal(
                size=s.shape, scale=0.02), jnp.float32),
        moe_mod.moe_specs(cfg),
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "init"))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 32, 64)),
                    jnp.float32)
    y, aux = moe_mod.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # expert load fractions sum to ≤ 1 (= 1 when nothing dropped)
    load = np.asarray(aux["expert_load"])
    assert load.sum() <= 1.0 + 1e-5
    assert float(aux["load_balance_loss"]) >= 0.99  # ≥1 at uniform-ish


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence."""
    from repro.models.ssm import _ssd_chunked
    rng = np.random.default_rng(2)
    B, L, H, P, G, N = 1, 48, 2, 4, 1, 8
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    y, hT = _ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    # naive recurrence
    h = np.zeros((B, H, P, N))
    ys = []
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, Bm, Cm))
    An, Dn = np.asarray(A), np.asarray(D)
    for t in range(L):
        decay = np.exp(dtn[:, t] * An)                    # (B,H)
        xdt = xn[:, t] * dtn[:, t][..., None]             # (B,H,P)
        h = h * decay[:, :, None, None] + np.einsum(
            "bhp,bgn->bhpn", xdt, Bn[:, t])
        yt = np.einsum("bgn,bhpn->bhp", Cn[:, t], h) + xn[:, t] * Dn[:, None]
        ys.append(yt)
    ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), h, atol=2e-3, rtol=2e-3)


def test_cell_support_matrix():
    """The 40-cell support matrix matches DESIGN.md §Arch-applicability."""
    total, runnable, skipped = 0, 0, 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            total += 1
            ok, why = cell_supported(cfg, shape)
            runnable += ok
            skipped += not ok
    assert total == 40
    assert skipped == 8   # hubert×2 decode shapes + 6 full-attn long_500k
    assert runnable == 32


def test_model_flops_per_token_moe_discount():
    dense = get_config("gemma-7b")
    moe = get_config("olmoe-1b-7b")
    f_moe = tr.model_flops_per_token(moe)
    n_total = tr.count_params(moe)
    assert f_moe < 6 * n_total  # routed experts discounted to top_k/E


def test_int8_kv_decode():
    """HC2: int8 KV cache decode tracks the bf16 path within the expected
    quantization band on a fp32 tiny model."""
    cfg = tiny(get_config("qwen1.5-32b"), dtype="float32",
               n_kv_heads=4)
    params = tr.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                              cfg.vocab)
    ref, _, _ = tr.forward(params, {"tokens": toks}, cfg, mode="train")
    errs = {}
    for kvd in ("bf16", "int8"):
        cache = tr.init_cache(cfg, 2, 24, kv_dtype=kvd)
        lengths = jnp.zeros((2,), jnp.int32)
        e = []
        for t in range(20):
            logits, cache = tr.decode_step(params, cache,
                                           toks[:, t:t + 1], lengths, cfg)
            e.append(float(jnp.max(jnp.abs(logits - ref[:, t]))))
            lengths = lengths + 1
        errs[kvd] = max(e)
    assert errs["bf16"] < 2e-3
    assert errs["int8"] < 1.0          # quantization band
    # int8 halves the cache footprint
    c8 = tr.init_cache(cfg, 2, 24, kv_dtype="int8")
    c16 = tr.init_cache(cfg, 2, 24, kv_dtype="bf16")
    bytes8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c8))
    bytes16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c16))
    assert bytes8 < 0.62 * bytes16
