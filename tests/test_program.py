"""`repro.program`: ahead-of-time compiled GAN executables.

Pins the API-redesign contract: bit-parity with the legacy per-call
dispatch threading on every runnable backend, one traced executable per
program (zero per-call re-resolution), JSON round-trip including
tuned-plan export to a planner-less process, and stale/corrupt program
files degrading to fresh resolution.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gans import GAN_MODELS
from repro.core.dataflow import DataflowPolicy
from repro.core.dataflow import conv as df_conv
from repro.core.dataflow import tconv as df_tconv
from repro.models.gan import (GanConfig, discriminator_epilogues,
                              generator_epilogues, init_gan)
from repro.program import (PROGRAM_FORMAT_VERSION, Program, ProgramSpec,
                           load_or_build)
from repro.tune import Plan, Planner, set_planner
from repro.tune.zoo import layer_plan_keys


@pytest.fixture(autouse=True)
def _isolated_planner():
    set_planner(None)
    yield
    set_planner(None)


# The concrete backends runnable on the CPU CI host (compiled
# pallas-tpu needs TPU hardware; its resolution path is pinned below).
RUNNABLE = ("polyphase", "zero-insert", "pallas-interpret")


def _legacy_generator_apply(params, z, cfg, policy):
    """The pre-Program per-call threading, verbatim: re-resolves
    config → policy → epilogues at every call site."""
    g_layers, _ = cfg.layers
    first = g_layers[0]
    x = z @ params["proj_w"] + params["proj_b"]
    x = x.reshape((z.shape[0],) + tuple(first.in_spatial) + (first.cin,))
    x = jax.nn.relu(x)
    for i, (l, ep) in enumerate(zip(g_layers,
                                    generator_epilogues(g_layers))):
        op = df_tconv if l.transposed else df_conv
        x = op(x, params[f"t{i}_w"], l.strides, l.paddings,
               policy=policy, bias=params[f"t{i}_b"], epilogue=ep)
    return x


def _legacy_discriminator_apply(params, img, cfg, policy):
    _, d_layers = cfg.layers
    x = img
    for i, (l, ep) in enumerate(zip(d_layers,
                                    discriminator_epilogues(d_layers))):
        x = df_conv(x, params[f"c{i}_w"], l.strides, l.paddings,
                    policy=policy, bias=params[f"c{i}_b"], epilogue=ep)
    return x.reshape(img.shape[0], -1).mean(axis=-1)


# ---------------------------------------------------------------------------
# Bit-parity vs the legacy path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["polyphase", "zero-insert"])
@pytest.mark.parametrize("name", sorted(GAN_MODELS))
def test_program_matches_legacy_every_model(name, backend):
    """Acceptance: Program.apply is bit-identical to the legacy
    generator_apply threading for every Table-I model."""
    cfg = GanConfig(name=name, channel_scale=0.0625, backend=backend)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))
    prog = Program.build(cfg, 2, "generator")
    legacy = _legacy_generator_apply(g, z, cfg, cfg.policy)
    np.testing.assert_array_equal(np.asarray(prog.apply(g, z)),
                                  np.asarray(legacy))


def test_program_matches_legacy_pallas_interpret():
    """The kernel backend (interpret mode on CPU): same contract."""
    cfg = GanConfig(name="dcgan", channel_scale=0.03125,
                    backend="pallas-interpret")
    g, d = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.z_dim))
    prog = Program.build(cfg, 1, "generator")
    assert all(le.backend == "pallas-interpret"
               for le in prog.spec.layers)
    img = prog.apply(g, z)
    np.testing.assert_array_equal(
        np.asarray(img),
        np.asarray(_legacy_generator_apply(g, z, cfg, cfg.policy)))
    d_prog = Program.build(cfg, 1, "discriminator")
    np.testing.assert_array_equal(
        np.asarray(d_prog.apply(d, img)),
        np.asarray(_legacy_discriminator_apply(d, img, cfg,
                                               cfg.policy)))


def test_discriminator_program_matches_legacy():
    cfg = GanConfig(name="dcgan", channel_scale=0.0625)
    _, d = init_gan(cfg, jax.random.PRNGKey(0))
    img = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64, 3))
    prog = Program.build(cfg, 2, "discriminator")
    np.testing.assert_array_equal(
        np.asarray(prog.apply(d, img)),
        np.asarray(_legacy_discriminator_apply(d, img, cfg,
                                               cfg.policy)))


def test_pallas_tpu_program_builds_and_round_trips():
    """A TPU-pinned program can't execute on this host, but its spec
    must build, describe, and survive JSON — that is the shippable
    artifact a TPU box loads."""
    cfg = GanConfig(name="dcgan", channel_scale=0.0625,
                    backend="pallas-tpu")
    spec = ProgramSpec.build(cfg, 8, "generator")
    assert all(le.backend == "pallas-tpu" and le.source == "pinned"
               for le in spec.layers)
    assert ProgramSpec.from_json(spec.to_json()) == spec
    assert "pallas-tpu" in spec.describe()


# ---------------------------------------------------------------------------
# One traced executable per program; zero per-call re-resolution.
# ---------------------------------------------------------------------------

def test_single_trace_per_shape():
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    prog = Program.build(cfg, 2, "generator")
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))
    for _ in range(3):
        prog.apply(g, z)
    assert prog.traces == 1
    # a new batch shape is a retrace of the same frozen records,
    # not a rebuild — the planning batch doesn't constrain apply
    prog.apply(g, jax.random.normal(jax.random.PRNGKey(2),
                                    (5, cfg.z_dim)))
    assert prog.traces == 2


def test_auto_program_resolves_once_not_per_call():
    """backend='auto' resolution happens at build: the planner is
    consulted once per layer, and repeated apply calls (and retraces)
    never touch it again."""
    planner = set_planner(Planner())
    cfg = GanConfig(name="dcgan", channel_scale=0.03125, backend="auto")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    g_layers, _ = cfg.layers
    prog = Program.build(cfg, 2, "generator")    # lookups, no measuring
    assert planner.lookups == len(g_layers)
    assert planner.measurements == 0
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))
    for _ in range(3):
        prog.apply(g, z)
    prog.apply(g, jax.random.normal(jax.random.PRNGKey(2),
                                    (4, cfg.z_dim)))
    assert planner.lookups == len(g_layers)      # unchanged
    assert prog.traces == 2


def test_program_jaxpr_is_resolution_free():
    """The traced computation is pure array ops on the frozen records —
    building the jaxpr works with no planner in the process at all."""
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    prog = Program.build(cfg, 2, "generator")
    z = jnp.zeros((2, cfg.z_dim), jnp.float32)
    jaxpr = jax.make_jaxpr(prog.forward)(g, z)
    assert len(jaxpr.jaxpr.eqns) > 0
    from repro.tune import get_planner
    assert get_planner(create=False) is None


# ---------------------------------------------------------------------------
# Differentiability (training path).
# ---------------------------------------------------------------------------

def test_program_forward_is_differentiable():
    cfg = GanConfig(name="dcgan", channel_scale=0.03125,
                    backend="pallas-interpret")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    prog = Program.build(cfg, 1, "generator")
    z = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.z_dim))

    def loss(g):
        return jnp.sum(prog.forward(g, z) ** 2)

    grads = jax.grad(loss)(g)
    assert set(grads) == set(g)
    assert all(np.isfinite(np.asarray(v)).all() for v in grads.values())


def test_make_gan_train_step_builds_programs_once():
    from repro.train.loop import make_gan_train_step
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, d = init_gan(cfg, jax.random.PRNGKey(0))
    step, (g_prog, d_prog) = make_gan_train_step(cfg, 2, g_lr=1e-3)
    assert g_prog.spec.role == "generator"
    assert d_prog.spec.role == "discriminator"
    batch = {"z": jax.random.normal(jax.random.PRNGKey(1),
                                    (2, cfg.z_dim)),
             "real": jnp.zeros((2, 64, 64, 3), jnp.float32)}
    state, metrics = step((g, d), batch)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # the step embeds the programs' forward, not their jitted apply
    assert g_prog.traces == 0


# ---------------------------------------------------------------------------
# JSON round-trip and export.
# ---------------------------------------------------------------------------

def _tuned_spec(cfg, batch=2):
    """A spec whose plans came from the autotuner: pallas-interpret with
    explicit block shapes on every generator layer."""
    planner = Planner()
    g_layers, _ = cfg.layers
    for _, key in layer_plan_keys(g_layers, batch=batch,
                                  epilogues=generator_epilogues(
                                      g_layers)):
        planner.put(key, Plan(backend="pallas-interpret", blocks=None,
                              measured_us=7.0))
    return ProgramSpec.build(cfg, batch, "generator",
                             policy=DataflowPolicy(backend="auto"),
                             planner=planner)


def test_tuned_spec_json_round_trip():
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    spec = _tuned_spec(cfg)
    assert all(le.source == "tuned" and le.measured_us == 7.0
               for le in spec.layers)
    doc = json.loads(json.dumps(spec.to_json()))   # through real JSON
    spec2 = ProgramSpec.from_json(doc)
    assert spec2 == spec
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))
    np.testing.assert_array_equal(
        np.asarray(Program(spec).apply(g, z)),
        np.asarray(Program(spec2).apply(g, z)))


def test_tuned_blocks_survive_round_trip(tmp_path):
    """Explicit Pallas tile shapes are part of the exported program."""
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    planner = Planner()
    g_layers, _ = cfg.layers
    keys = layer_plan_keys(g_layers, batch=1,
                           epilogues=generator_epilogues(g_layers))
    # g1: 4x4 -> 8x8, stride 2: phase-plane qy=4; cin=32*scale=1? use
    # known-valid divisors from the layer channels
    first = g_layers[0]
    planner.put(keys[0][1], Plan(backend="pallas-interpret",
                                 blocks=(2, first.cin, first.cout)))
    spec = ProgramSpec.build(cfg, 1, "generator",
                             policy=DataflowPolicy(backend="auto"),
                             planner=planner)
    assert spec.layers[0].blocks == (2, first.cin, first.cout)
    path = tmp_path / "prog.json"
    spec.save(path)
    loaded = ProgramSpec.load(path)
    assert loaded == spec
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.z_dim))
    ref = Program.build(cfg, 1, "generator").apply(g, z)
    np.testing.assert_allclose(np.asarray(Program(loaded).apply(g, z)),
                               np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_exported_program_serves_planner_less_process(tmp_path):
    """Acceptance: to_json → from_json → apply on a fresh process with
    no planner measurements — the measurement counter stays 0 and no
    process-wide planner is even created."""
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    spec = _tuned_spec(cfg)
    path = tmp_path / "prog.json"
    spec.save(path)
    code = f"""
import jax, numpy as np
from repro.models.gan import GanConfig, init_gan
from repro.program import Program, ProgramSpec
from repro.tune import Planner, get_planner, set_planner

planner = set_planner(Planner())      # would record any consult
spec = ProgramSpec.load({str(path)!r})
cfg = GanConfig(name="dcgan", channel_scale=0.03125)
g, _ = init_gan(cfg, jax.random.PRNGKey(0))
prog = Program(spec)
img = prog.apply(g, jax.random.normal(jax.random.PRNGKey(1),
                                      (2, cfg.z_dim)))
assert img.shape == (2, 64, 64, 3), img.shape
assert all(le.source == "tuned" for le in spec.layers)
assert planner.measurements == 0, planner.measurements
assert planner.lookups == 0, planner.lookups
print("SERVED", planner.measurements, planner.lookups)
"""
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               PYTHONPATH=f"{root / 'src'}:"
                          f"{os.environ.get('PYTHONPATH', '')}",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         cwd=str(root), env=env)
    assert out.returncode == 0, out.stderr
    assert "SERVED 0 0" in out.stdout


# ---------------------------------------------------------------------------
# Stale / corrupt program files degrade to fresh resolution.
# ---------------------------------------------------------------------------

CFG = dict(name="dcgan", channel_scale=0.03125)


def _assert_rebuilt(path, cfg=None):
    cfg = cfg or GanConfig(**CFG)
    prog, loaded = load_or_build(path, cfg, 2, "generator")
    assert not loaded
    assert len(prog.spec.layers) == len(cfg.layers[0])
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    img = prog.apply(g, jax.random.normal(jax.random.PRNGKey(1),
                                          (2, cfg.z_dim)))
    assert img.shape[0] == 2
    return prog


def test_missing_program_file_builds_fresh(tmp_path):
    _assert_rebuilt(tmp_path / "nope.json")


def test_corrupt_program_file_builds_fresh(tmp_path):
    path = tmp_path / "prog.json"
    path.write_text("{not json")
    _assert_rebuilt(path)


def test_wrong_version_builds_fresh(tmp_path):
    cfg = GanConfig(**CFG)
    doc = ProgramSpec.build(cfg, 2, "generator").to_json()
    doc["version"] = PROGRAM_FORMAT_VERSION + 1
    path = tmp_path / "prog.json"
    path.write_text(json.dumps(doc))
    _assert_rebuilt(path)


def test_unknown_backend_builds_fresh(tmp_path):
    cfg = GanConfig(**CFG)
    doc = ProgramSpec.build(cfg, 2, "generator").to_json()
    doc["layers"][0]["backend"] = "systolic-array-9000"
    path = tmp_path / "prog.json"
    path.write_text(json.dumps(doc))
    _assert_rebuilt(path)


def test_stale_blocks_build_fresh(tmp_path):
    cfg = GanConfig(**CFG)
    doc = ProgramSpec.build(cfg, 2, "generator").to_json()
    doc["layers"][0]["backend"] = "pallas-interpret"
    doc["layers"][0]["blocks"] = [3, 7, 11]   # divides nothing
    path = tmp_path / "prog.json"
    path.write_text(json.dumps(doc))
    _assert_rebuilt(path)


def test_geometry_drift_builds_fresh(tmp_path):
    """A program frozen for one channel scale must not serve a config
    built at another — that is workload drift, not a valid program."""
    other = GanConfig(name="dcgan", channel_scale=0.0625)
    path = tmp_path / "prog.json"
    ProgramSpec.build(other, 2, "generator").save(path)
    prog = _assert_rebuilt(path)
    assert prog.spec.channel_scale == 0.03125


def test_corrupt_epilogue_fields_build_fresh(tmp_path):
    """from_json validates hard: a file with an unknown activation or a
    bias layer missing its param name must fail at load (and so degrade
    via load_or_build), not at first trace."""
    cfg = GanConfig(**CFG)
    doc = ProgramSpec.build(cfg, 2, "generator").to_json()
    bad_act = json.loads(json.dumps(doc))
    bad_act["layers"][0]["activation"] = "gelu"
    with pytest.raises(ValueError, match="activation"):
        ProgramSpec.from_json(bad_act)
    bad_bias = json.loads(json.dumps(doc))
    bad_bias["layers"][0]["b_param"] = None
    with pytest.raises(ValueError, match="b_param"):
        ProgramSpec.from_json(bad_bias)
    path = tmp_path / "prog.json"
    path.write_text(json.dumps(bad_act))
    _assert_rebuilt(path)


def test_good_program_file_loads(tmp_path):
    cfg = GanConfig(**CFG)
    spec = ProgramSpec.build(
        cfg, 2, "generator",
        policy=DataflowPolicy(backend="zero-insert"))
    path = tmp_path / "prog.json"
    spec.save(path)
    prog, loaded = load_or_build(path, cfg, 2, "generator")
    assert loaded
    # the file's resolution wins over what the config would pick now
    assert all(le.backend == "zero-insert" for le in prog.spec.layers)


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def test_cli_describe_export_load(tmp_path):
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               PYTHONPATH=f"{root / 'src'}:"
                          f"{os.environ.get('PYTHONPATH', '')}",
               JAX_PLATFORMS="cpu")
    path = tmp_path / "prog.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.program", "dcgan",
         "--channel-scale", "0.0625", "--role", "generator",
         "--export", str(path)],
        capture_output=True, text=True, cwd=str(root), env=env)
    assert out.returncode == 0, out.stderr
    assert "program dcgan/generator" in out.stdout
    assert path.exists()
    out2 = subprocess.run(
        [sys.executable, "-m", "repro.program", "dcgan",
         "--channel-scale", "0.0625", "--load", str(path)],
        capture_output=True, text=True, cwd=str(root), env=env)
    assert out2.returncode == 0, out2.stderr
    assert "program dcgan/generator" in out2.stdout
    assert "rebuilt" not in out2.stdout


# ---------------------------------------------------------------------------
# Guard rails.
# ---------------------------------------------------------------------------

def test_bad_role_raises():
    cfg = GanConfig(**CFG)
    with pytest.raises(ValueError, match="role"):
        ProgramSpec.build(cfg, 2, "critic")


def test_server_rejects_wrong_role_program():
    from repro.serve.gan import GanServer
    cfg = GanConfig(**CFG)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    d_prog = Program.build(cfg, 2, "discriminator")
    with pytest.raises(ValueError, match="generator"):
        GanServer(cfg, g, batch_size=2, program=d_prog)


def test_server_rejects_mismatched_workload_program():
    """A program frozen for a different model (or scaling) of the served
    config fails at construction with a clear error, not as a shape
    mismatch inside the first generate() trace."""
    from repro.serve.gan import GanServer
    cfg = GanConfig(**CFG)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    other = Program.build(GanConfig(name="gpgan", channel_scale=0.03125),
                          2, "generator")
    with pytest.raises(ValueError, match="different workload"):
        GanServer(cfg, g, batch_size=2, program=other)
    scaled = Program.build(GanConfig(name="dcgan", channel_scale=0.0625),
                           2, "generator")
    with pytest.raises(ValueError, match="different workload"):
        GanServer(cfg, g, batch_size=2, program=scaled)


def test_cli_measure_exports_tuned_program(tmp_path):
    """--backend auto --measure tunes plan misses at build, so the
    exported file carries tuned (not heuristic) layer resolutions."""
    from repro.program.__main__ import main
    plans = tmp_path / "plans.json"
    path = tmp_path / "prog.json"
    rc = main(["dcgan", "--channel-scale", "0.03125", "--batch", "2",
               "--role", "generator", "--backend", "auto",
               "--plans", str(plans), "--measure",
               "--export", str(path)])
    assert rc == 0
    spec = ProgramSpec.load(path)
    assert all(le.source == "tuned" for le in spec.layers)
    assert plans.exists()   # measured plans persisted for reuse
