"""Property tests for the static GANAX schedule (μop compilation)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.scheduler import (  # noqa: E402
    make_schedule, transposed_conv_output_size)

geom = st.tuples(
    st.integers(2, 9),    # in_size
    st.integers(1, 6),    # kernel
    st.integers(1, 4),    # stride
)


def _valid(geoms):
    return all(p < k for (_, k, _), p in geoms)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(2, 9), st.integers(1, 6),
                          st.integers(1, 4), st.integers(0, 3)),
                min_size=1, max_size=3))
def test_phase_partition_covers_output(dims):
    """Every output position belongs to exactly one phase, and phase-plane
    sizes sum to the full output (the row reorganization is a bijection)."""
    dims = [(n, k, s, min(p, k - 1)) for (n, k, s, p) in dims]
    in_sizes = [d[0] for d in dims]
    kernel = [d[1] for d in dims]
    strides = [d[2] for d in dims]
    pads = [d[3] for d in dims]
    sched = make_schedule(in_sizes, kernel, strides, pads)
    for d, dim_phases in enumerate(sched.dims):
        covered = []
        for pd in dim_phases:
            covered.extend(range(pd.phase, sched.out_sizes[d],
                                 strides[d]))
            assert pd.out_size == len(
                range(pd.phase, sched.out_sizes[d], strides[d]))
        assert sorted(covered) == list(range(sched.out_sizes[d]))


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(2, 9), st.integers(1, 6),
                          st.integers(1, 4), st.integers(0, 3)),
                min_size=1, max_size=3))
def test_taps_are_residue_classes(dims):
    """Phase taps are exactly {k : k ≡ (φ+p) mod s} — the filter-row
    reorganization groups."""
    dims = [(n, k, s, min(p, k - 1)) for (n, k, s, p) in dims]
    sched = make_schedule([d[0] for d in dims], [d[1] for d in dims],
                          [d[2] for d in dims], [d[3] for d in dims])
    for d, dim_phases in enumerate(sched.dims):
        k, s, p = sched.kernel[d], sched.strides[d], sched.paddings[d]
        for pd in dim_phases:
            expect = [t for t in range(k) if (t - (pd.phase + p)) % s == 0]
            assert list(pd.taps) == expect


@settings(max_examples=150, deadline=None)
@given(st.integers(2, 10), st.integers(1, 6), st.integers(1, 4),
       st.integers(0, 3), st.integers(1, 32), st.integers(1, 32))
def test_mac_counts(n, k, s, p, cin, cout):
    p = min(p, k - 1)
    sched = make_schedule((n, n), (k, k), (s, s), (p, p))
    conseq = sched.consequential_macs(cin, cout)
    total = sched.zero_inserted_macs(cin, cout)
    assert 0 <= conseq <= total
    if s == 1:
        assert conseq == total          # SIMD mode: nothing skipped
        assert sched.inconsequential_fraction() == 0.0
    # brute-force consequential count
    out = transposed_conv_output_size(n, k, s, p)
    brute = 0
    for oy in range(out):
        for ox in range(out):
            ty = len([t for t in range(k)
                      if (oy + p - t) % s == 0])
            tx = len([t for t in range(k)
                      if (ox + p - t) % s == 0])
            brute += ty * tx
    assert conseq == brute * cin * cout


def test_paper_example():
    """The paper's running example: 4×4 input, 5×5 kernel, s=2, p=2
    (Fig. 4/5): two row patterns, taps {0,2,4} and {1,3}."""
    sched = make_schedule((4, 4), (5, 5), (2, 2), (2, 2))
    y = sched.dims[0]
    assert y[0].taps == (0, 2, 4) and y[1].taps == (1, 3)
    assert sched.out_sizes == (7, 7)
    # ~73.6% of baseline MACs are inconsequential for this geometry
    assert 0.70 < sched.inconsequential_fraction() < 0.78


def test_tap_tables_padded_consistent():
    sched = make_schedule((4, 4), (5, 5), (2, 2), (2, 2))
    t = sched.tap_tables()
    assert t["n_taps"].sum() == sum(
        int(np.prod([pd.n_taps for pd in sched.phase_dims(i)]))
        for i in range(sched.n_phases))
    assert (t["tap_dx"] >= 0).all()
    # longest-first ordering
    assert list(t["n_taps"]) == sorted(t["n_taps"], reverse=True)


def test_invalid_geometry_raises():
    with pytest.raises(ValueError):
        make_schedule((4,), (3,), (2,), (3,))   # p >= k
    with pytest.raises(ValueError):
        make_schedule((4, 4), (3,), (2, 2), (0, 0))  # rank mismatch
