"""CI bench regression gate (`benchmarks/check_regression.py`): metric
extraction, per-model threshold comparison in both metric directions,
missing-model coverage failure, the override env, and --update."""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks import check_regression as cr  # noqa: E402

DATAFLOW = {
    "dcgan": {"polyphase_us": 1000.0, "zero_insert_us": 2000.0,
              "wallclock_speedup": 2.0, "fused_us": 900.0,
              "unfused_us": 950.0, "fused_speedup": 1.05},
    "3dgan": {"polyphase_us": 9000.0, "zero_insert_us": 63000.0,
              "wallclock_speedup": 7.0},
}
TUNE = {
    "dcgan": {"generator_tuned_us": 500.0,
              "generator_heuristic_us": 550.0},
    "_meta": {"repeats": 3},
}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_extract_gated_metrics_only():
    fresh = cr.extract(DATAFLOW, TUNE)
    assert fresh["dataflow"]["3dgan"] == {"polyphase_us": 9000.0,
                                          "wallclock_speedup": 7.0}
    # the fused path is gated via its wall-clock; the informational
    # unfused_us / fused_speedup rows are not
    assert fresh["dataflow"]["dcgan"] == {"polyphase_us": 1000.0,
                                          "wallclock_speedup": 2.0,
                                          "fused_us": 900.0}
    assert fresh["tune"] == {"dcgan": {"generator_tuned_us": 500.0}}
    assert "_meta" not in fresh["tune"]          # meta rows never gate
    # null / non-numeric metric values are dropped, not compared
    assert cr.extract({"m": {"polyphase_us": None}}, {}) == \
        {"dataflow": {}, "tune": {}}


def test_cap_metric_gates_absolutely():
    """obs_overhead_pct gates against its absolute cap — no baseline
    value needed (relative gating of a near-zero pct is meaningless)."""
    base = cr.extract(DATAFLOW, TUNE)
    fresh = json.loads(json.dumps(base))
    fresh["dataflow"]["dcgan"]["obs_overhead_pct"] = 1.5   # under cap
    failures, lines = cr.compare(base, fresh, threshold=0.25)
    assert failures == []
    assert any("obs_overhead_pct" in ln and "cap" in ln for ln in lines)
    fresh["dataflow"]["dcgan"]["obs_overhead_pct"] = 3.7   # over cap
    failures, _ = cr.compare(base, fresh, threshold=0.25)
    assert len(failures) == 1
    assert "obs_overhead_pct" in failures[0] and "cap" in failures[0]
    # a cap metric present in the baseline but absent from the fresh
    # artifacts is a coverage regression like any other
    base2 = json.loads(json.dumps(base))
    base2["dataflow"]["dcgan"]["obs_overhead_pct"] = 0.5
    failures, _ = cr.compare(base2, base, threshold=0.25)
    assert any("obs_overhead_pct" in f and "missing" in f
               for f in failures)


def test_extract_accepts_zero_pct():
    """A clamped overhead of exactly 0 must survive extraction (it is
    the best possible value); zero wall-clock rows are still dropped as
    bogus."""
    df = {"dcgan": {"obs_overhead_pct": 0.0, "polyphase_us": 0.0}}
    fresh = cr.extract(df, {})
    assert fresh["dataflow"]["dcgan"] == {"obs_overhead_pct": 0.0}


def test_fused_wallclock_regression_gated(tmp_path, capsys):
    """A slowdown confined to the fused path fails the gate."""
    base = cr.extract(DATAFLOW, TUNE)
    fresh = json.loads(json.dumps(base))
    fresh["dataflow"]["dcgan"]["fused_us"] = 1500.0     # +67%
    failures, _ = cr.compare(base, fresh, threshold=0.25)
    assert len(failures) == 1
    assert "dcgan/fused_us" in failures[0]


def test_compare_directions_and_threshold():
    base = cr.extract(DATAFLOW, TUNE)
    fresh = json.loads(json.dumps(base))         # deep copy
    # wall-clock ("lower is better"): +30% is a regression, -30% is not
    fresh["dataflow"]["dcgan"]["polyphase_us"] = 1300.0
    fresh["tune"]["dcgan"]["generator_tuned_us"] = 350.0
    # ratio ("higher is better"): dropping 7.0 -> 5.0 is a regression
    fresh["dataflow"]["3dgan"]["wallclock_speedup"] = 5.0
    failures, lines = cr.compare(base, fresh, threshold=0.25)
    assert len(failures) == 2
    assert any("dcgan/polyphase_us" in f for f in failures)
    assert any("3dgan/wallclock_speedup" in f for f in failures)
    # within-threshold and improved metrics pass
    failures, _ = cr.compare(base, base, threshold=0.25)
    assert failures == []


def test_widened_threshold_directions():
    """traffic rows gate at a multiplied threshold ("lower*2" /
    "higher*2"): a +30% p99 or -30% throughput passes where a standard
    row would fail, but a 2x swing still gates."""
    df = {"dcgan": {"polyphase_us": 1000.0, "wallclock_speedup": 2.0,
                    "traffic_high_p99_us": 10000.0,
                    "traffic_high_throughput_sps": 400.0}}
    base = cr.extract(df, {})
    assert base["dataflow"]["dcgan"]["traffic_high_p99_us"] == 10000.0
    fresh = json.loads(json.dumps(base))
    fresh["dataflow"]["dcgan"]["traffic_high_p99_us"] = 13000.0   # +30%
    fresh["dataflow"]["dcgan"]["traffic_high_throughput_sps"] = 290.0
    failures, _ = cr.compare(base, fresh, threshold=0.25)
    assert failures == []        # within the widened (50%) threshold
    fresh["dataflow"]["dcgan"]["traffic_high_p99_us"] = 21000.0   # +110%
    fresh["dataflow"]["dcgan"]["traffic_high_throughput_sps"] = 180.0
    failures, _ = cr.compare(base, fresh, threshold=0.25)
    assert len(failures) == 2
    assert any("traffic_high_p99_us" in f and "+50%" in f
               for f in failures)
    assert any("traffic_high_throughput_sps" in f for f in failures)
    # the same +30% on a standard-threshold row still fails
    fresh2 = json.loads(json.dumps(base))
    fresh2["dataflow"]["dcgan"]["polyphase_us"] = 1300.0
    failures, _ = cr.compare(base, fresh2, threshold=0.25)
    assert len(failures) == 1 and "polyphase_us" in failures[0]


def test_compare_missing_model_fails():
    base = cr.extract(DATAFLOW, TUNE)
    fresh = json.loads(json.dumps(base))
    del fresh["dataflow"]["3dgan"]
    failures, _ = cr.compare(base, fresh, threshold=0.25)
    assert any("missing" in f for f in failures)
    # the reverse (a new model) reports but does not fail
    failures, lines = cr.compare(fresh, base, threshold=0.25)
    assert failures == [] and any("new" in ln for ln in lines)


def test_main_update_then_green_gate(tmp_path, capsys):
    df = _write(tmp_path, "BENCH_dataflow.json", DATAFLOW)
    tn = _write(tmp_path, "BENCH_tune.json", TUNE)
    bl = str(tmp_path / "BENCH_baseline.json")
    assert cr.main(["--baseline", bl, "--dataflow", df, "--tune", tn,
                    "--update"]) == 0
    assert json.loads(Path(bl).read_text())["threshold"] == 0.25
    assert cr.main(["--baseline", bl, "--dataflow", df,
                    "--tune", tn]) == 0
    assert "No regressions" in capsys.readouterr().out


def test_main_regression_fails_and_override_passes(tmp_path, capsys,
                                                   monkeypatch):
    df = _write(tmp_path, "BENCH_dataflow.json", DATAFLOW)
    tn = _write(tmp_path, "BENCH_tune.json", TUNE)
    bl = str(tmp_path / "BENCH_baseline.json")
    cr.main(["--baseline", bl, "--dataflow", df, "--tune", tn, "--update"])
    slow = json.loads(json.dumps(DATAFLOW))
    slow["dcgan"]["polyphase_us"] *= 2            # 2x slowdown
    df2 = _write(tmp_path, "BENCH_dataflow2.json", slow)

    monkeypatch.delenv("BENCH_GATE_OVERRIDE", raising=False)
    assert cr.main(["--baseline", bl, "--dataflow", df2,
                    "--tune", tn]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "bench-regression-override" in out

    monkeypatch.setenv("BENCH_GATE_OVERRIDE", "1")
    assert cr.main(["--baseline", bl, "--dataflow", df2,
                    "--tune", tn]) == 0
    assert "not failing the job" in capsys.readouterr().out
    # "0" means unset, matching the workflow's ternary expression
    monkeypatch.setenv("BENCH_GATE_OVERRIDE", "0")
    assert cr.main(["--baseline", bl, "--dataflow", df2,
                    "--tune", tn]) == 1
    capsys.readouterr()


@pytest.mark.parametrize("threshold,rc", [(0.9, 0), (0.1, 1)])
def test_main_threshold_flag(tmp_path, capsys, threshold, rc):
    df = _write(tmp_path, "BENCH_dataflow.json", DATAFLOW)
    tn = _write(tmp_path, "BENCH_tune.json", TUNE)
    bl = str(tmp_path / "BENCH_baseline.json")
    cr.main(["--baseline", bl, "--dataflow", df, "--tune", tn, "--update"])
    slow = json.loads(json.dumps(DATAFLOW))
    slow["dcgan"]["polyphase_us"] *= 1.5          # +50% slowdown
    df2 = _write(tmp_path, "BENCH_dataflow2.json", slow)
    assert cr.main(["--baseline", bl, "--dataflow", df2, "--tune", tn,
                    "--threshold", str(threshold)]) == rc
    capsys.readouterr()
