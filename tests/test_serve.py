"""Serving engine: continuous batching, sampling, engine-vs-manual decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer as tr
from repro.serve.engine import DecodeEngine, EngineConfig, Request
from repro.serve.sampling import sample

TINY = dataclasses.replace(
    get_config("qwen1.5-32b"), n_layers=2, d_model=32, d_ff=64, vocab=64,
    n_heads=2, n_kv_heads=2, head_dim=16)


def test_sampling_greedy():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
    toks = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks), [1, 0])


def test_sampling_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -5.0]])
    for seed in range(10):
        t = sample(logits, jax.random.PRNGKey(seed), temperature=1.0,
                   top_k=2)
        assert int(t[0]) in (1, 2)


def test_engine_matches_manual_decode():
    params = tr.init(TINY, jax.random.PRNGKey(0))
    prompt = [3, 1, 4, 1, 5]
    ecfg = EngineConfig(n_slots=2, max_len=32, max_new=6, temperature=0.0)
    engine = DecodeEngine(TINY, params, ecfg)
    req = Request(rid=0, prompt=list(prompt))
    engine.run([req])
    # manual greedy loop
    cache = tr.init_cache(TINY, 1, 32)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, pcache, _ = tr.forward(params, {"tokens": toks}, TINY,
                                   mode="prefill")
    cache = jax.tree.map(
        lambda c, p: c.at[:, :1, :p.shape[2]].set(p)
        if p.ndim >= 3 and p.shape[2] == len(prompt) else
        c.at[:, :1].set(p), cache, pcache)
    cur = int(jnp.argmax(logits[0, -1]))
    manual = [cur]
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(5):
        lg, cache = tr.decode_step(params, cache,
                                   jnp.asarray([[cur]], jnp.int32),
                                   lengths, TINY)
        cur = int(jnp.argmax(lg[0]))
        manual.append(cur)
        lengths = lengths + 1
    assert req.generated == manual, (req.generated, manual)


def test_engine_continuous_batching_slot_reuse():
    params = tr.init(TINY, jax.random.PRNGKey(1))
    ecfg = EngineConfig(n_slots=2, max_len=24, max_new=4, temperature=0.0)
    engine = DecodeEngine(TINY, params, ecfg)
    reqs = [Request(rid=i, prompt=[1 + i, 2 + i]) for i in range(5)]
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    # more requests than slots ⇒ slots must have been recycled
    assert engine.steps >= 4


def test_engine_eos_frees_slot():
    params = tr.init(TINY, jax.random.PRNGKey(2))
    # find greedy first token for a prompt, use it as EOS
    ecfg0 = EngineConfig(n_slots=1, max_len=16, max_new=2)
    e0 = DecodeEngine(TINY, params, ecfg0)
    r0 = Request(rid=0, prompt=[5, 6])
    e0.run([r0])
    eos = r0.generated[1]
    ecfg = EngineConfig(n_slots=1, max_len=16, max_new=8, eos_id=eos)
    engine = DecodeEngine(TINY, params, ecfg)
    r = Request(rid=0, prompt=[5, 6])
    engine.run([r])
    assert r.done and r.generated[-1] == eos
    assert len(r.generated) <= 8
