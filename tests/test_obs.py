"""repro.obs: span tracer semantics (nesting, jit interaction, the
disabled no-op pin), metrics registry (histogram percentiles vs a numpy
reference, labels, collectors), export round-trips, and the
instrumentation acceptance paths (serve spans/latency, program --stats).
"""

import bisect
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import Histogram, Registry

REPO = Path(__file__).resolve().parent.parent


def _cli_env() -> dict:
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("REPRO_OBS", None)      # the CLIs under test run untraced
    return env


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with tracing disabled (the process
    default); sinks created mid-test are dropped, never flushed."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# Span tracer.
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_close_order():
    sink = obs.enable()
    with obs.trace("outer", a=1):
        with obs.trace("inner"):
            pass
        with obs.trace("inner2"):
            pass
    spans = sink.spans()
    # spans are emitted as they close: children before the parent
    assert [s["name"] for s in spans] == ["inner", "inner2", "outer"]
    assert {s["name"]: s["depth"] for s in spans} == \
        {"outer": 0, "inner": 1, "inner2": 1}
    inner, inner2, outer = spans
    assert outer["attrs"] == {"a": 1}
    assert outer["ts_us"] <= inner["ts_us"]
    assert inner["ts_us"] <= inner2["ts_us"]
    assert outer["dur_us"] >= inner["dur_us"] + inner2["dur_us"] - 1e-3
    assert obs.tracer.current_depth() == 0          # stack fully popped


def test_span_mid_attrs_error_attr_and_decorator():
    sink = obs.enable()
    with obs.trace("s") as sp:
        sp.set(found=3)
    assert sink.spans("s")[0]["attrs"] == {"found": 3}

    with pytest.raises(ValueError):
        with obs.trace("boom"):
            raise ValueError("x")
    assert sink.spans("boom")[0]["attrs"]["error"] == "ValueError"

    @obs.trace("deco", kind="fn")
    def g(v):
        return v + 1

    assert g(1) == 2
    assert sink.spans("deco")[0]["attrs"] == {"kind": "fn"}
    obs.disable()
    assert g(2) == 3                                # inert when disabled
    assert len(sink.spans("deco")) == 1


def test_span_inside_jit_fires_once_at_trace_time():
    """A span in a jitted function records trace time exactly once —
    it can never fire inside the compiled computation."""
    sink = obs.enable()

    @jax.jit
    def f(x):
        with obs.trace("jit.body"):
            return x * 2.0

    for i in range(4):
        f(jnp.float32(i)).block_until_ready()
    assert len(sink.spans("jit.body")) == 1


def test_disabled_is_a_no_op_and_jaxpr_identical():
    sink = obs.enable()
    obs.disable()
    with obs.trace("x", a=1) as sp:
        sp.set(b=2)
    obs.event("y", n=3)
    assert len(sink) == 0                           # zero sink writes
    assert not obs.is_enabled()

    # spans are host-side only: the traced computation is identical
    # with tracing on or off
    def f(x):
        with obs.trace("span.inside", k="v"):
            return jnp.sin(x) + 1.0

    x = jnp.arange(4.0)
    jaxpr_off = str(jax.make_jaxpr(f)(x))
    obs.enable()
    jaxpr_on = str(jax.make_jaxpr(f)(x))
    assert jaxpr_on == jaxpr_off


# ---------------------------------------------------------------------------
# Metrics.
# ---------------------------------------------------------------------------

def test_counter_gauge_labels_and_snapshot():
    reg = Registry()
    reg.counter("hits").inc()
    reg.counter("hits").inc(2)                      # get-or-create
    assert reg.counter("hits").value == 3
    assert reg.counter("hits", server="a") is not reg.counter("hits")
    reg.counter("hits", server="a").inc(5)
    reg.gauge("depth").set(7)
    snap = reg.snapshot()
    assert snap["counters"] == {"hits": 3, "hits{server=a}": 5}
    assert snap["gauges"] == {"depth": 7.0}
    with pytest.raises(ValueError):
        reg.counter("hits").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("hits")                           # kind collision


def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(7)
    data = rng.uniform(10.0, 1e5, size=4000)
    h = Histogram("lat_us")
    for v in data:
        h.observe(v)
    assert h.count == len(data)
    assert np.isclose(h.sum, data.sum())
    for p in (0, 50, 90, 99, 100):
        ref = float(np.percentile(data, p))
        got = h.percentile(p)
        # error bounded by the containing bucket's width
        i = bisect.bisect_left(h.bounds, ref)
        lo = data.min() if i == 0 else h.bounds[i - 1]
        hi = data.max() if i == len(h.bounds) else h.bounds[i]
        assert abs(got - ref) <= (hi - lo), (p, got, ref)
        assert data.min() <= got <= data.max()
    assert set(h.percentiles()) == {"p50", "p90", "p99"}


def test_histogram_edge_cases():
    h = Histogram("h", bounds=(1.0, 2.0, 4.0))
    assert np.isnan(h.percentile(50))               # empty
    h.observe(3.0)
    assert h.percentile(50) == 3.0                  # single → clamped
    h.observe(100.0)                                # overflow bucket
    assert h.count == 2
    assert 4.0 < h.percentile(100) <= 100.0         # clamped to max
    with pytest.raises(ValueError):
        Histogram("bad", bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        h.percentile(101)


def test_collectors_return_copies():
    reg = Registry()
    live = {"hits": 1}
    reg.register_collector("src", lambda: live)
    reg.register_collector("dead", lambda: None)    # source not alive
    out = reg.collect()
    assert out == {"src": {"hits": 1}}
    out["src"]["hits"] = 99                         # mutate the copy
    assert live["hits"] == 1                        # original untouched


def test_process_collectors_registered():
    import repro.core.dataflow  # noqa: F401
    import repro.tune  # noqa: F401
    stats = obs.collect()
    assert "dataflow.uop_cache" in stats
    assert {"hits", "misses"} <= set(stats["dataflow.uop_cache"])


# ---------------------------------------------------------------------------
# Export round-trips and the CLI.
# ---------------------------------------------------------------------------

def test_jsonl_trace_event_roundtrip(tmp_path):
    sink = obs.enable()
    with obs.trace("a", k="v"):
        obs.event("e", n=1)
    obs.counter("c", model="dcgan").inc(4)
    obs.histogram("h").observe(12.5)
    obs.flush_metrics()
    records = list(sink.records)

    back = obs.from_trace_events(obs.to_trace_events(records))
    want = [r for r in records if r["type"] in ("span", "event")]
    got = [r for r in back if r["type"] in ("span", "event")]
    assert got == want                              # lossless
    # the flush carries the whole (process-wide) registry; pick out the
    # metrics this test created
    c = next(r for r in back if r.get("kind") == "counter"
             and r["name"] == "c")
    assert c["value"] >= 4 and c["labels"] == {"model": "dcgan"}
    hist = next(r for r in back if r.get("kind") == "histogram"
                and r["name"] == "h")
    assert hist["count"] >= 1

    jl, te = tmp_path / "t.jsonl", tmp_path / "t.trace.json"
    obs.write_jsonl(records, jl)
    obs.write_trace_events(records, te)
    assert obs.read_records(jl) == records          # format sniffing
    doc = json.loads(te.read_text())
    assert all("ph" in e for e in doc["traceEvents"])
    assert [r for r in obs.read_records(te) if r["type"] == "span"] \
        == [r for r in records if r["type"] == "span"]

    text = obs.summarize(records)
    assert "a" in text and "c{model=dcgan}" in text and "p50" in text


def test_jsonl_sink_live_file_and_env_opt_in(tmp_path):
    path = tmp_path / "run.jsonl"
    obs.enable(str(path))
    with obs.trace("s"):
        pass
    obs.flush_metrics()
    obs.disable()
    records = obs.read_records(path)
    assert records[0]["type"] == "header"
    assert any(r["type"] == "span" and r["name"] == "s"
               for r in records)


def test_obs_cli_summarize_and_convert(tmp_path):
    src = tmp_path / "run.jsonl"
    obs.write_jsonl([{"type": "span", "name": "x", "ts_us": 1.0,
                      "dur_us": 5.0, "tid": 0, "depth": 0,
                      "attrs": {}}], src)
    out = tmp_path / "out.trace.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.obs", str(src),
         "--perfetto", str(out)],
        capture_output=True, text=True, cwd=str(REPO), env=_cli_env())
    assert r.returncode == 0, r.stderr
    assert "1 spans" in r.stdout
    assert json.loads(out.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# Instrumentation acceptance.
# ---------------------------------------------------------------------------

def test_serve_generate_spans_and_latency():
    from repro.models.gan import GanConfig, init_gan
    from repro.serve.gan import GanServer

    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    srv = GanServer(cfg, g, batch_size=2)
    sink = obs.enable()
    srv.generate(3)
    srv.generate(1)
    obs.disable()

    reqs = sink.spans("serve.generate")
    assert [s["attrs"]["n"] for s in reqs] == [3, 1]
    assert reqs[0]["attrs"]["batches"] == 2
    assert reqs[1]["attrs"]["batches"] == 0          # all from buffer
    # the traced call nests the program span and its per-layer spans
    apply_spans = sink.spans("program.apply")
    assert apply_spans and apply_spans[0]["attrs"]["traced"] is True
    layers = sink.spans("program.layer")
    assert layers, "per-layer spans missing"
    assert {s["attrs"]["source"] for s in layers} <= \
        {"pinned", "tuned", "heuristic"}
    assert all(s["attrs"]["backend"] for s in layers)
    assert all(s["depth"] > apply_spans[0]["depth"] for s in layers)

    # registry-backed accounting: attribute API + invariant intact
    assert srv.samples_served + srv.samples_buffered + \
        srv.samples_discarded == srv.batches_served * 2
    lat = srv._m_request_us
    assert lat.count == 2 and lat.percentile(99) >= lat.percentile(50)
    snap = obs.snapshot()
    key = f"serve.samples_served{{server={srv.server_id}}}"
    assert snap["counters"][key] == srv.samples_served


def test_resolution_counters_and_program_stats_flag():
    from repro.models.gan import GanConfig
    from repro.program import ProgramSpec

    before = obs.snapshot()["counters"]
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    spec = ProgramSpec.build(cfg, 2, "generator")
    after = obs.snapshot()["counters"]

    def delta(name):
        return after.get(name, 0) - before.get(name, 0)

    assert delta("dataflow.resolve") == len(spec.layers)
    assert delta("program.builds") == 1
    by_source = sum(delta(f"dataflow.resolve.{s}")
                    for s in ("pinned", "tuned", "heuristic"))
    assert by_source == len(spec.layers)

    r = subprocess.run(
        [sys.executable, "-m", "repro.program", "dcgan",
         "--role", "generator", "--stats"],
        capture_output=True, text=True, cwd=str(REPO), env=_cli_env())
    assert r.returncode == 0, r.stderr
    assert "resolution stats:" in r.stdout
    assert "dataflow.resolve" in r.stdout
