"""Distribution: sharding rules, flash-decode, elastic checkpoint restore.

Multi-device tests run in subprocesses (XLA locks the device count at
first init; the main test process keeps the single real CPU device) via
the shared ``conftest.run_forced_devices`` helper.
"""

import pytest
from conftest import run_forced_devices

from repro.sharding.rules import Rules, spec_for_axes


class TestRules:
    def test_divisible_maps_to_model(self):
        class FakeMesh:
            shape = {"data": 4, "model": 4}
        spec = spec_for_axes(("embed", "mlp"), (64, 128), FakeMesh(),
                             Rules())
        assert tuple(spec) == (None, "model")

    def test_non_divisible_falls_back(self):
        class FakeMesh:
            shape = {"data": 4, "model": 16}
        spec = spec_for_axes(("embed", "ssm_heads"), (64, 50), FakeMesh(),
                             Rules())
        assert tuple(spec) == ()

    def test_fsdp_adds_data_axis(self):
        class FakeMesh:
            shape = {"data": 4, "model": 4}
        spec = spec_for_axes(("embed", "mlp"), (64, 128), FakeMesh(),
                             Rules(fsdp=True))
        assert tuple(spec) == ("data", "model")

    def test_no_duplicate_axes(self):
        class FakeMesh:
            shape = {"data": 4, "model": 4}
        spec = spec_for_axes(("mlp", "heads"), (64, 128), FakeMesh(),
                             Rules())
        assert tuple(spec).count("model") <= 1


@pytest.mark.slow
def test_flash_decode_matches_dense():
    run_forced_devices("""
        from repro.models.attention import decode_attention, flash_decode
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        B, T, H, hd = 2, 64, 2, 16
        q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
        lens = jnp.asarray([13, 40])
        ref = decode_attention(q, k, v, lens)
        with mesh:
            got = jax.jit(lambda *a: flash_decode(*a, mesh=mesh))(
                q, k, v, lens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)
        print("PASS")
    """)


@pytest.mark.slow
def test_small_mesh_train_step_lowering():
    """End-to-end distributed lowering on 8 fake devices: a small model's
    train_step compiles with FSDP+TP shardings and runs one real step."""
    run_forced_devices("""
        import dataclasses
        from repro.configs.base import get_config
        from repro.models import transformer as tr
        from repro.models.common import spec_shapes
        from repro.sharding import rules as R
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_state import init_train_state, \\
            make_train_step
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            get_config("gemma3-4b"), n_layers=3, d_model=64, d_ff=128,
            vocab=512, n_heads=4, n_kv_heads=2, head_dim=16,
            local_window=8, local_global_pattern=(2, 1))
        rules = R.Rules(fsdp=True)
        axes = tr.model_axes(cfg)
        shapes = spec_shapes(tr.model_specs(cfg))
        p_sh = R.param_shardings(mesh, axes, shapes, rules)
        flags = tr.RunFlags(mesh=mesh, remat=True)
        step = make_train_step(cfg, AdamWConfig(), flags)
        with mesh:
            state = init_train_state(cfg, jax.random.PRNGKey(0))
            state = dict(state, params=jax.device_put(state["params"],
                                                      p_sh))
            toks = jnp.ones((8, 32), jnp.int32)
            jit_step = jax.jit(step, donate_argnums=(0,))
            state, m = jit_step(state, {"tokens": toks})
            state, m = jit_step(state, {"tokens": toks})
        assert np.isfinite(float(m["total_loss"]))
        print("PASS")
    """)


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore on (2,2) and on (8,) — global values
    must be identical (elastic scaling contract)."""
    run_forced_devices("""
        import tempfile
        from repro.train import checkpoint as ckpt
        d = tempfile.mkdtemp()
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
        ckpt.save({"w": xa}, d, 1)
        for shape, axes, spec in [
                ((2, 2), ("data", "model"), P("model", "data")),
                ((8,), ("data",), P(None, "data"))]:
            mesh_b = jax.make_mesh(shape, axes)
            sh = {"w": NamedSharding(mesh_b, spec)}
            out = ckpt.restore({"w": jnp.zeros((8, 8))}, d, 1, sh)
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          np.asarray(x))
            assert out["w"].sharding.spec == spec
        print("PASS")
    """)


@pytest.mark.slow
def test_gradient_compression_dcn_equivalence():
    """int8-compressed gradient sync converges like uncompressed on a
    2-pod mesh (pure-DP toy model)."""
    run_forced_devices("""
        from repro.train.compress import make_int8_grad_transform
        rng = np.random.default_rng(0)
        w = jnp.zeros((16,))
        X = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
        ytrue = X @ jnp.asarray(rng.normal(size=(16,)), jnp.float32)
        def loss(w):
            return jnp.mean((X @ w - ytrue) ** 2)
        transform, init_err = make_int8_grad_transform({"w": w})
        err = init_err()
        w_c, w_u = w, w
        for i in range(300):
            g = jax.grad(loss)(w_u)
            w_u = w_u - 0.01 * g
            g2 = jax.grad(loss)(w_c)
            q, err = transform({"w": g2}, err)
            w_c = w_c - 0.01 * q["w"]
        # compressed training matches uncompressed to high precision
        # (the toy problem's convergence floor at this lr is ~0.017)
        assert float(loss(w_c)) < 5e-2, float(loss(w_c))
        assert abs(float(loss(w_c)) - float(loss(w_u))) < 1e-3
        print("PASS")
    """)
