"""Pallas unified conv/tconv kernel vs the pure-jnp oracle (interpret
mode: exact kernel semantics executed on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import ganax_conv, ganax_conv_transpose
from repro.kernels.ref import conv_ref, tconv_ref

TCONV_CASES = [
    # (x_shape, w_shape, strides, pads)
    ((2, 4, 4, 8), (5, 5, 8, 16), (2, 2), (2, 2)),
    ((1, 8, 8, 16), (4, 4, 16, 8), (2, 2), (1, 1)),
    ((1, 5, 3, 4), (3, 5, 4, 4), (3, 2), (1, 2)),
    ((2, 6, 6, 3), (3, 3, 3, 4), (1, 1), (1, 1)),   # SIMD mode (s=1)
    ((1, 4, 4, 128), (4, 4, 128, 256), (2, 2), (1, 1)),  # MXU-aligned
    ((1, 4, 4, 1), (2, 2, 1, 1), (2, 2), (0, 0)),
    ((1, 3, 7, 2), (4, 3, 2, 5), (2, 3), (1, 0)),
]

CONV_CASES = [
    ((2, 8, 8, 8), (3, 3, 8, 16), (1, 1), (1, 1)),
    ((1, 16, 16, 4), (4, 4, 4, 8), (2, 2), (1, 1)),
    ((2, 9, 9, 8), (5, 5, 8, 8), (2, 2), (2, 2)),
    ((1, 8, 8, 128), (4, 4, 128, 128), (2, 2), (1, 1)),
    ((1, 7, 7, 3), (3, 3, 3, 5), (3, 3), (0, 0)),
]

# Volumetric (3-D) cases — the 3D-GAN layer family plus mixed strides
# and the kernel<stride degenerate phases.
TCONV3D_CASES = [
    ((1, 3, 3, 3, 4), (4, 4, 4, 4, 8), (2, 2, 2), (1, 1, 1)),
    ((2, 2, 3, 2, 2), (3, 3, 3, 2, 3), (1, 1, 1), (1, 1, 1)),
    ((1, 3, 2, 3, 2), (3, 4, 3, 2, 4), (3, 2, 1), (1, 1, 0)),
    ((1, 2, 2, 2, 2), (2, 2, 2, 2, 3), (3, 3, 3), (0, 0, 0)),
]

CONV3D_CASES = [
    ((1, 5, 5, 5, 4), (3, 3, 3, 4, 8), (1, 1, 1), (1, 1, 1)),
    ((2, 6, 6, 6, 2), (4, 4, 4, 2, 4), (2, 2, 2), (1, 1, 1)),
    ((1, 7, 5, 7, 2), (3, 3, 3, 2, 2), (3, 2, 3), (0, 1, 0)),
]


@pytest.mark.parametrize("xs,ws,s,p", TCONV_CASES)
def test_tconv_kernel_vs_oracle(xs, ws, s, p):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = tconv_ref(x, w, s, p)
    got = ganax_conv_transpose(x, w, s, p, interpret=True)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("xs,ws,s,p", CONV_CASES)
def test_conv_kernel_vs_oracle(xs, ws, s, p):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = conv_ref(x, w, s, p)
    got = ganax_conv(x, w, s, p, interpret=True)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("xs,ws,s,p", TCONV3D_CASES)
def test_tconv3d_kernel_vs_oracle(xs, ws, s, p):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = tconv_ref(x, w, s, p)
    got = ganax_conv_transpose(x, w, s, p, interpret=True)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("xs,ws,s,p", CONV3D_CASES)
def test_conv3d_kernel_vs_oracle(xs, ws, s, p):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = conv_ref(x, w, s, p)
    got = ganax_conv(x, w, s, p, interpret=True)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


# (blocks, case) — the autotuner's tunable tile shapes: qy-row tiling
# and sub-128 channel tiles must be bit-compatible with the defaults.
BLOCK_CASES = [
    ((1, 4, 4, 8), (4, 4, 8, 16), (2, 2), (1, 1), (2, 4, 8)),
    ((1, 4, 4, 8), (4, 4, 8, 16), (2, 2), (1, 1), (1, 8, 16)),
    ((2, 6, 6, 4), (3, 3, 4, 4), (1, 1), (1, 1), (3, 2, 2)),
    ((1, 5, 3, 4), (3, 5, 4, 4), (3, 2), (1, 2), (1, 4, 2)),
]


@pytest.mark.parametrize("xs,ws,s,p,blocks", BLOCK_CASES)
def test_tconv_kernel_block_shapes(xs, ws, s, p, blocks):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = tconv_ref(x, w, s, p)
    got = ganax_conv_transpose(x, w, s, p, interpret=True, blocks=blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("xs,ws,s,p,blocks", [
    ((1, 8, 8, 8), (3, 3, 8, 16), (2, 2), (1, 1), (1, 4, 8)),
    ((1, 16, 16, 4), (4, 4, 4, 8), (2, 2), (1, 1), (4, 2, 4)),
])
def test_conv_kernel_block_shapes(xs, ws, s, p, blocks):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = conv_ref(x, w, s, p)
    got = ganax_conv(x, w, s, p, interpret=True, blocks=blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("blocks,err", [
    ((3, 8, 16), "block_qy"),
    ((4, 3, 16), "block_cin"),
    ((4, 8, 5), "block_cout"),
    ((0, 8, 16), "block_qy"),
    ("bogus", "triple"),
])
def test_invalid_blocks_raise(blocks, err):
    x = jnp.zeros((1, 4, 4, 8), jnp.float32)
    w = jnp.zeros((4, 4, 8, 16), jnp.float32)
    with pytest.raises(ValueError, match=err):
        ganax_conv_transpose(x, w, (2, 2), (1, 1), interpret=True,
                             blocks=blocks)


# 3-D blocks are (block_qz, block_qy, block_cin, block_cout) quadruples:
# output-plane tiling alongside the row tiling.
TCONV3D_BLOCK_CASES = [
    ((1, 3, 3, 3, 4), (4, 4, 4, 4, 8), (2, 2, 2), (1, 1, 1), (1, 3, 4, 8)),
    ((1, 3, 3, 3, 4), (4, 4, 4, 4, 8), (2, 2, 2), (1, 1, 1), (3, 1, 2, 4)),
    ((1, 2, 4, 4, 4), (3, 3, 3, 4, 4), (1, 1, 1), (1, 1, 1), (2, 2, 2, 2)),
]


@pytest.mark.parametrize("xs,ws,s,p,blocks", TCONV3D_BLOCK_CASES)
def test_tconv3d_kernel_block_shapes(xs, ws, s, p, blocks):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = tconv_ref(x, w, s, p)
    got = ganax_conv_transpose(x, w, s, p, interpret=True, blocks=blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_conv3d_kernel_block_shapes():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 6, 2)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4, 4, 2, 4)), jnp.float32)
    ref = conv_ref(x, w, (2, 2, 2), (1, 1, 1))
    got = ganax_conv(x, w, (2, 2, 2), (1, 1, 1), interpret=True,
                     blocks=(1, 3, 2, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("blocks,err", [
    ((2, 3, 4, 8), "block_qz"),
    ((3, 2, 4, 8), "block_qy"),
    ((3, 3, 3, 8), "block_cin"),
    ((3, 3, 4, 5), "block_cout"),
    ((3, 4, 8), "quadruple"),         # 2-D triple on a 3-D layer
])
def test_invalid_blocks_raise_3d(blocks, err):
    x = jnp.zeros((1, 3, 3, 3, 4), jnp.float32)
    w = jnp.zeros((4, 4, 4, 4, 8), jnp.float32)
    with pytest.raises(ValueError, match=err):
        ganax_conv_transpose(x, w, (2, 2, 2), (1, 1, 1), interpret=True,
                             blocks=blocks)


def test_kernel3d_lowers_to_mosaic():
    """The volumetric kernel must lower for the real TPU target too."""
    from repro.compat import lower_as_mlir
    x = jnp.zeros((1, 4, 4, 4, 128), jnp.float32)
    w = jnp.zeros((4, 4, 4, 128, 128), jnp.float32)

    def f(x, w):
        return ganax_conv_transpose(x, w, (2, 2, 2), (1, 1, 1),
                                    interpret=False)

    mlir = str(lower_as_mlir(f, x, w)).lower()
    assert "tpu" in mlir, "no TPU custom-call in the lowered module"


@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-3),
    (jnp.bfloat16, 1.5e-1),
])
def test_kernel_dtypes(dtype, tol):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1, 4, 4, 8)), dtype)
    w = jnp.asarray(rng.normal(size=(4, 4, 8, 8)), dtype)
    ref = tconv_ref(x, w, (2, 2), (1, 1))
    got = ganax_conv_transpose(x, w, (2, 2), (1, 1), interpret=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_kernel_lowers_to_mosaic():
    """The kernel must lower for the real TPU target (Mosaic MLIR), not
    just run in interpret mode."""
    from repro.compat import lower_as_mlir
    x = jnp.zeros((1, 4, 4, 128), jnp.float32)
    w = jnp.zeros((4, 4, 128, 128), jnp.float32)

    def f(x, w):
        return ganax_conv_transpose(x, w, (2, 2), (1, 1), interpret=False)

    mlir = str(lower_as_mlir(f, x, w)).lower()
    assert "tpu" in mlir, "no TPU custom-call in the lowered module"


def test_unified_simd_mode_matches_tconv_stride1():
    """Paper's 'unified' claim: a stride-1 tconv and the conv path produce
    consistent results through the same kernel."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 4)), jnp.float32)
    t = ganax_conv_transpose(x, w, (1, 1), (1, 1), interpret=True)
    # stride-1 tconv(p) == correlation with flipped kernel pad (k-1-p)
    c = ganax_conv(x, jnp.flip(w, (0, 1)), (1, 1), (1, 1), interpret=True)
    np.testing.assert_allclose(np.asarray(t), np.asarray(c),
                               atol=1e-3, rtol=1e-3)
