"""ISA-level validation: the GANAX machine (strided index generators +
address-free execute μops) reproduces the reference transposed conv
exactly, executes only consequential MACs, and beats the conventional
(zero-inserted) dataflow run on the *same* machine."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import make_schedule
from repro.core.tconv import tconv_ganax, zero_insert
from repro.core.uop import StridedIndexGenerator, run_tconv_on_machine

CASES = [
    (4, 4, 5, 2, 2, 4, 4),
    (4, 4, 4, 2, 1, 2, 3),
    (5, 3, 3, 3, 1, 4, 2),
    (6, 6, 3, 1, 1, 4, 4),
    (8, 8, 2, 2, 0, 4, 4),
]


def _ref(x, w, s, p):
    out = tconv_ganax(jnp.asarray(x[None, :, :, None], jnp.float32),
                      jnp.asarray(w[:, :, None, None], jnp.float32),
                      (s, s), (p, p))
    return np.asarray(out)[0, :, :, 0]


@pytest.mark.parametrize("h,w_,k,s,p,npv,npe", CASES)
def test_machine_exact(h, w_, k, s, p, npv, npe):
    rng = np.random.default_rng(h * 100 + k * 10 + s)
    x = rng.normal(size=(h, w_))
    w = rng.normal(size=(k, k))
    sched = make_schedule((h, w_), (k, k), (s, s), (p, p))
    out, stats = run_tconv_on_machine(x, w, sched, n_pvs=npv,
                                      pes_per_pv=npe)
    np.testing.assert_allclose(out, _ref(x, w, s, p), atol=1e-6,
                               rtol=1e-6)
    # fine-grain zero skipping: executed MACs == consequential MACs
    assert stats["macs"] == sched.consequential_macs(1, 1)


def test_machine_beats_conventional_dataflow():
    """Speedup at ISA level: run the conventional dataflow (zero-inserted
    input, all taps) through the same machine and compare MAC cycles."""
    rng = np.random.default_rng(0)
    h, k, s, p = 8, 4, 2, 1
    x = rng.normal(size=(h, h))
    w = rng.normal(size=(k, k))
    sched = make_schedule((h, h), (k, k), (s, s), (p, p))
    _, ganax = run_tconv_on_machine(x, w, sched, n_pvs=4, pes_per_pv=4)

    # conventional: dense conv over the explicitly zero-inserted input
    xe = np.asarray(zero_insert(
        jnp.asarray(x[None, :, :, None]), (s, s)))[0, :, :, 0]
    sched_base = make_schedule(xe.shape, (k, k), (1, 1), (p, p))
    out_base, base = run_tconv_on_machine(xe, w, sched_base, n_pvs=4,
                                          pes_per_pv=4)
    assert base["macs"] == sched.zero_inserted_macs(1, 1)
    speedup = base["macs"] / ganax["macs"]
    assert speedup > 2.0   # 4×4 stride-2 → ~75% inconsequential
    # and the baseline run computes the same function
    np.testing.assert_allclose(out_base, _ref(x, w, s, p), atol=1e-6,
                               rtol=1e-6)


def test_index_generator_semantics():
    g = StridedIndexGenerator()
    g.configure("addr", 2)
    g.configure("step", 3)
    g.configure("end", 11)
    g.configure("repeat", 2)
    g.configure("offset", 100)
    g.start()
    seq = [g.emit() for _ in range(6)]
    # 2,5,8 wrap → 0,3,6 wrap? 2+3k mod 11: 2,5,8,(11→0),3,6,(9...)
    assert seq == [102, 105, 108, 100, 103, 106]
    g2 = StridedIndexGenerator()
    g2.configure("repeat", 1)
    g2.configure("end", 2)
    g2.configure("step", 1)
    g2.start()
    g2.emit()
    g2.emit()
    assert not g2.running
    with pytest.raises(RuntimeError):
        g2.emit()


def test_machine_utilization_reported():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 8))
    w = rng.normal(size=(4, 4))
    sched = make_schedule((8, 8), (4, 4), (2, 2), (1, 1))
    _, st = run_tconv_on_machine(x, w, sched, n_pvs=2, pes_per_pv=2)
    assert 0.0 < st["utilization"] <= 1.0
    assert len(st["pv_cycles"]) == 2
