"""`repro.quant`: mixed-precision storage and int8-weight programs.

Enforces the checked-in tolerance gates of ``repro.quant.tolerance``:
single-op forward+grad parity across every runnable backend × op kind ×
spatial rank × stride, full-generator forward+grad gates for every
Table-I model at bf16/f16, and the int8-weight export → JSON → serve
round-trip (bit-stable, planner-less, version-gated).
"""

import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gans import GAN_MODELS
from repro.core.dataflow import DataflowPolicy
from repro.core.dataflow import conv as df_conv
from repro.core.dataflow import tconv as df_tconv
from repro.models.gan import GanConfig, init_gan
from repro.program import Program, ProgramSpec, load_or_build
from repro.quant import (Precision, canonical_dtype, dequantize_weight,
                         model_tolerance, op_tolerance, quantize_program,
                         quantize_weight, storage_dtype, storage_itemsize)
from repro.quant.weights import validate_quantized

# The concrete backends runnable on the CPU CI host (compiled
# pallas-tpu needs TPU hardware; its resolution path is pinned below).
RUNNABLE = ("polyphase", "zero-insert", "pallas-interpret")
DTYPES = ("bfloat16", "float16")


# ---------------------------------------------------------------------------
# Precision spec.
# ---------------------------------------------------------------------------

def test_canonical_dtype_aliases():
    for alias in ("bf16", "bfloat16"):
        assert canonical_dtype(alias) == "bfloat16"
    for alias in ("f16", "fp16", "half", "float16"):
        assert canonical_dtype(alias) == "float16"
    for alias in ("f32", "fp32", "float32"):
        assert canonical_dtype(alias) == "float32"
    assert canonical_dtype(jnp.bfloat16) == "bfloat16"


@pytest.mark.parametrize("bad", ["float64", "int8", "complex64", "nope"])
def test_unsupported_storage_dtype_raises(bad):
    with pytest.raises(ValueError, match="storage dtype"):
        canonical_dtype(bad)


def test_precision_spec():
    p = Precision("bf16")
    assert p.storage == "bfloat16"
    assert p.storage_dtype == jnp.dtype(jnp.bfloat16)
    assert p.accum_dtype == jnp.dtype(jnp.float32)
    assert p.itemsize == 2
    assert not p.is_f32
    assert Precision().is_f32
    assert "float32 accumulate" in p.describe()
    assert storage_itemsize("float32") == 4
    assert storage_dtype("float16") == jnp.dtype(jnp.float16)


def test_gan_config_canonicalizes_and_validates_dtype():
    assert GanConfig("dcgan", dtype="bf16").dtype == "bfloat16"
    assert GanConfig("dcgan").dtype == "float32"
    with pytest.raises(ValueError, match="storage dtype"):
        GanConfig("dcgan", dtype="float64")


# ---------------------------------------------------------------------------
# Single-op parity sweep: backend × kind × rank × stride × dtype.
# ---------------------------------------------------------------------------

# (kind, nd) → stride-parametrized small geometry
_GEOMS = {
    ("tconv", 2): lambda s: ((1, 4, 4, 4), (3, 3, 4, 4), (s, s), (1, 1)),
    ("tconv", 3): lambda s: ((1, 2, 3, 2, 2), (3, 3, 3, 2, 3),
                             (s, s, s), (1, 1, 1)),
    ("conv", 2): lambda s: ((1, 7, 7, 4), (3, 3, 4, 4), (s, s), (1, 1)),
    ("conv", 3): lambda s: ((1, 5, 5, 5, 2), (3, 3, 3, 2, 2),
                            (s, s, s), (1, 1, 1)),
}


def _rel_l2(a, b):
    return float(jnp.linalg.norm((a - b).ravel()) /
                 (jnp.linalg.norm(b.ravel()) + 1e-30))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("stride", [1, 2, 3])
@pytest.mark.parametrize("kind,nd", sorted(_GEOMS))
@pytest.mark.parametrize("backend", RUNNABLE)
def test_op_parity_low_precision(backend, kind, nd, stride, dtype):
    """Forward within the checked-in (rtol, atol) of the f32 run and
    both cotangents within the relative-L2 gate, for every runnable
    backend, op kind, spatial rank, and stride."""
    xs, ws, strides, pads = _GEOMS[(kind, nd)](stride)
    policy = DataflowPolicy(backend=backend)
    op = df_tconv if kind == "tconv" else df_conv
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    d = jnp.dtype(storage_dtype(dtype))

    y32 = op(x, w, strides, pads, policy=policy)
    y = op(x.astype(d), w.astype(d), strides, pads, policy=policy)
    assert y.dtype == d
    rtol, atol = op_tolerance(dtype, "fwd")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y32), rtol=rtol, atol=atol)

    gx32, gw32 = jax.grad(
        lambda x, w: jnp.sum(op(x, w, strides, pads,
                                policy=policy) ** 2),
        argnums=(0, 1))(x, w)
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(op(x.astype(d), w.astype(d), strides,
                                pads, policy=policy)
                             .astype(jnp.float32) ** 2),
        argnums=(0, 1))(x, w)
    # grads land back in the params' dtype (f32): trainable as-is
    assert gx.dtype == gw.dtype == jnp.float32
    gate = op_tolerance(dtype, "grad_rel")
    assert _rel_l2(gx, gx32) < gate, "input cotangent drift"
    assert _rel_l2(gw, gw32) < gate, "weight cotangent drift"


# ---------------------------------------------------------------------------
# Model-level gates: every Table-I generator, forward + grad.
# ---------------------------------------------------------------------------

_SCALE = 0.0625   # the calibration configuration of repro.quant.tolerance


def _grad_tree_rel(a: dict, b: dict) -> float:
    num = sum(float(jnp.sum((a[k] - b[k]) ** 2)) for k in a)
    den = sum(float(jnp.sum(b[k] ** 2)) for k in b)
    return (num / max(den, 1e-30)) ** 0.5


def _f32_reference(name, backend="polyphase"):
    cfg = GanConfig(name, channel_scale=_SCALE, backend=backend)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim),
                          jnp.float32)
    prog = Program.build(cfg, 2, "generator")
    y = prog.forward(g, z)
    grads = jax.grad(lambda p: jnp.sum(prog.forward(p, z) ** 2))(g)
    return cfg, g, z, y, grads


@pytest.mark.parametrize("backend", ["polyphase", "zero-insert"])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("name", sorted(GAN_MODELS))
def test_model_low_precision_gates(name, dtype, backend):
    """Acceptance: every Table-I generator runs forward+grad at low
    storage precision within its checked-in tolerance."""
    cfg32, g, z, y32, g32 = _f32_reference(name, backend)
    cfg = dataclasses.replace(cfg32, dtype=dtype)
    prog = Program.build(cfg, 2, "generator")
    y = prog.forward(g, z)
    assert y.dtype == storage_dtype(dtype)
    gate = model_tolerance(name, dtype)
    drift = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y32)))
    assert drift < gate["output_atol"], (drift, gate)
    grads = jax.grad(lambda p: jnp.sum(
        prog.forward(p, z).astype(jnp.float32) ** 2))(g)
    assert all(v.dtype == jnp.float32 for v in grads.values())
    rel = _grad_tree_rel(grads, g32)
    assert rel < gate["grad_rel"], (rel, gate)


def test_model_bf16_pallas_interpret_kernel():
    """The kernel backend executes the bf16 program too (interpret
    mode = exact Pallas semantics): low-precision VMEM blocks, f32
    scratch accumulate, cast at the fused-epilogue flush."""
    cfg32, g, z, y32, g32 = _f32_reference("dcgan",
                                           backend="pallas-interpret")
    cfg = GanConfig("dcgan", channel_scale=_SCALE,
                    backend="pallas-interpret", dtype="bf16")
    prog = Program.build(cfg, 2, "generator")
    assert all(le.backend == "pallas-interpret"
               for le in prog.spec.layers)
    y = prog.forward(g, z)
    assert y.dtype == jnp.bfloat16
    gate = model_tolerance("dcgan", "bfloat16")
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - y32))) < \
        gate["output_atol"]
    grads = jax.grad(lambda p: jnp.sum(
        prog.forward(p, z).astype(jnp.float32) ** 2))(g)
    assert _grad_tree_rel(grads, g32) < gate["grad_rel"]


def test_bf16_pallas_tpu_program_pins_and_round_trips():
    """Acceptance for the hardware backend on a CPU host: the bf16
    TPU program builds resolution-pinned and survives JSON with its
    precision intact."""
    for name in sorted(GAN_MODELS):
        cfg = GanConfig(name, channel_scale=_SCALE,
                        backend="pallas-tpu", dtype="bf16")
        spec = ProgramSpec.build(cfg, 2, "generator")
        assert spec.dtype == "bfloat16"
        assert all(le.backend == "pallas-tpu" and le.source == "pinned"
                   for le in spec.layers)
        again = ProgramSpec.from_json(spec.to_json())
        assert again == spec and again.dtype == "bfloat16"


def test_discriminator_logits_stay_f32():
    cfg = GanConfig("dcgan", channel_scale=_SCALE, backend="polyphase",
                    dtype="bf16")
    _, d = init_gan(cfg, jax.random.PRNGKey(0))
    img = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64, 3))
    prog = Program.build(cfg, 2, "discriminator")
    logits = prog.forward(d, img)
    assert logits.dtype == jnp.float32      # loss input: full precision
    assert logits.shape == (2,)


def test_mixed_precision_train_step_keeps_f32_state():
    """bf16 storage trains: one adversarial step; params, optimizer
    state, and gradients stay f32 end to end."""
    from repro.train.loop import make_gan_train_step
    cfg = GanConfig("dcgan", channel_scale=_SCALE, backend="polyphase",
                    dtype="bf16")
    step, _ = make_gan_train_step(cfg, batch=2)
    g, d = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.z_dim))
    real = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64, 3))
    (g2, d2), metrics = step((g, d), {"z": z, "real": real})
    assert all(v.dtype == jnp.float32 for v in g2.values())
    assert all(v.dtype == jnp.float32 for v in d2.values())
    assert np.isfinite(float(metrics["g_loss"]))
    assert np.isfinite(float(metrics["d_loss"]))


def test_precision_is_its_own_tuning_workload():
    """The autotuner keys plans by dtype: a bf16 layer is a different
    workload than the same geometry at f32, so tuned f32 plans never
    leak into low-precision dispatches."""
    cfg32 = GanConfig("dcgan", channel_scale=_SCALE)
    cfgbf = dataclasses.replace(cfg32, dtype="bf16")
    k32 = {k for _, k in ProgramSpec.build(cfg32, 2,
                                           "generator").plan_keys()}
    kbf = {k for _, k in ProgramSpec.build(cfgbf, 2,
                                           "generator").plan_keys()}
    assert k32 and kbf and not (k32 & kbf)
    assert {k.dtype for k in kbf} == {"bfloat16"}
    assert {k.dtype for k in k32} == {"float32"}


# ---------------------------------------------------------------------------
# int8 weight quantization.
# ---------------------------------------------------------------------------

def test_quantize_weight_round_trip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 4, 8, 16)), jnp.float32)
    q, scale = quantize_weight(w)
    assert q.dtype == np.int8 and scale.dtype == np.float32
    assert q.shape == w.shape and scale.shape == (16,)
    assert int(np.abs(q).max()) <= 127
    back = dequantize_weight(q, scale, "float32")
    # per-channel symmetric: error bounded by scale/2 per element
    assert np.max(np.abs(np.asarray(back) - np.asarray(w)) /
                  scale.reshape(1, 1, 1, -1)) <= 0.5 + 1e-6


def test_quantize_weight_zero_channel_and_rank_guard():
    w = jnp.zeros((3, 3, 2, 4), jnp.float32)
    q, scale = quantize_weight(w)
    assert np.all(scale == 1.0) and np.all(q == 0)
    with pytest.raises(ValueError, match="rank"):
        quantize_weight(jnp.zeros((7,), jnp.float32))


def test_validate_quantized_rejects_corrupt_payloads():
    cfg = GanConfig("dcgan", channel_scale=_SCALE)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    spec = quantize_program(ProgramSpec.build(cfg, 2, "generator"), g)
    blob = json.loads(json.dumps(spec.quantized_params))
    validate_quantized(blob)                       # the good one passes
    bad = dict(blob, scheme="int4-groupwise")
    with pytest.raises(ValueError, match="scheme"):
        validate_quantized(bad)
    bad = json.loads(json.dumps(blob))
    first = next(k for k, v in bad["params"].items()
                 if v["kind"] == "int8")
    bad["params"][first]["values"]["data"] = "AAAA"  # truncated payload
    with pytest.raises(ValueError):
        validate_quantized(bad)


def test_quantize_program_wants_covering_params():
    cfg = GanConfig("dcgan", channel_scale=_SCALE)
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    spec = ProgramSpec.build(cfg, 2, "generator")
    incomplete = {k: v for k, v in g.items() if k != "t0_w"}
    with pytest.raises(ValueError, match="t0_w"):
        quantize_program(spec, incomplete)


@pytest.mark.parametrize("name", sorted(GAN_MODELS))
def test_int8_forward_gate_every_model(name):
    """The int8-weight export stays within its checked-in forward
    tolerance for every Table-I model (weights dequantized into the
    program's storage dtype at load)."""
    cfg32, g, z, y32, _ = _f32_reference(name)
    spec = quantize_program(
        ProgramSpec.build(cfg32, 2, "generator"), g)
    loaded = ProgramSpec.from_json(json.loads(json.dumps(
        spec.to_json())))
    prog = Program(loaded)
    assert prog.quantized
    params = prog.params
    y = prog.forward(params, z)
    gate = model_tolerance(name, "int8")["output_atol"]
    drift = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y32)))
    assert drift < gate, (drift, gate)
    # serving artifact: bit-stable across replays
    np.testing.assert_array_equal(np.asarray(prog.forward(params, z)),
                                  np.asarray(y))


def test_int8_export_round_trip_and_versioning(tmp_path):
    cfg = GanConfig("dcgan", channel_scale=_SCALE, dtype="bf16")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    spec = quantize_program(ProgramSpec.build(cfg, 2, "generator"), g)
    path = tmp_path / "prog.json"
    spec.save(path)
    doc = json.loads(path.read_text())
    assert doc["version"] == 3
    assert doc["dtype"] == "bfloat16"
    assert doc["quantized_params"]["scheme"].startswith("int8")
    loaded = ProgramSpec.load(path)
    assert loaded == spec
    prog = Program(loaded)
    # weights dequantize into the storage dtype; biases stay raw f32
    params = prog.params
    assert params["t0_w"].dtype == jnp.bfloat16
    assert params["t0_b"].dtype == jnp.float32
    assert "quant=int8" in repr(prog)
    assert "quant=int8" in loaded.describe()


def test_old_program_versions_load_as_f32_unquantized(tmp_path):
    """v1/v2 files predate the precision subsystem: they must load as
    float32 with no quantized payload (forward-compatible fields are
    ignored, not misread)."""
    cfg = GanConfig("dcgan", channel_scale=_SCALE)
    doc = ProgramSpec.build(cfg, 2, "generator").to_json()
    for version in (1, 2):
        old = json.loads(json.dumps(doc))
        old["version"] = version
        if version == 1:
            old.pop("mesh", None)
        # a v1/v2 writer never emitted these fields
        old.pop("dtype", None)
        old.pop("quantized_params", None)
        spec = ProgramSpec.from_json(old)
        assert spec.dtype == "float32"
        assert spec.quantized_params is None


def test_precision_drift_rebuilds_from_config(tmp_path):
    """dtype is part of the geometry signature: a program frozen at
    one storage precision must not serve a config wanting another."""
    cfg_bf = GanConfig("dcgan", channel_scale=_SCALE, dtype="bf16")
    path = tmp_path / "prog.json"
    ProgramSpec.build(cfg_bf, 2, "generator").save(path)
    cfg_f32 = GanConfig("dcgan", channel_scale=_SCALE)
    prog, loaded = load_or_build(path, cfg_f32, 2, "generator")
    assert not loaded
    assert prog.spec.dtype == "float32"


def test_int8_program_serves_planner_less_process(tmp_path):
    """Acceptance: the quantized export serves on a fresh process with
    zero planner measurements and zero extra inputs — the embedded
    weights are the parameters."""
    cfg = GanConfig("dcgan", channel_scale=_SCALE, dtype="bf16")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    spec = quantize_program(ProgramSpec.build(cfg, 2, "generator"), g)
    path = tmp_path / "prog.json"
    spec.save(path)
    code = f"""
import jax, numpy as np, jax.numpy as jnp
from repro.program import Program, ProgramSpec
from repro.tune import Planner, set_planner

planner = set_planner(Planner())      # would record any consult
spec = ProgramSpec.load({str(path)!r})
prog = Program(spec)
assert prog.quantized
z = jax.random.normal(jax.random.PRNGKey(1), (2, 100))
img = prog.apply(prog.params, z)
assert img.shape == (2, 64, 64, 3), img.shape
assert img.dtype == jnp.bfloat16, img.dtype
again = prog.apply(prog.params, z)
assert (np.asarray(img) == np.asarray(again)).all()
assert planner.measurements == 0, planner.measurements
assert planner.lookups == 0, planner.lookups
print("SERVED-INT8")
"""
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               PYTHONPATH=f"{root / 'src'}:"
                          f"{os.environ.get('PYTHONPATH', '')}",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         cwd=str(root), env=env)
    assert out.returncode == 0, out.stderr
    assert "SERVED-INT8" in out.stdout


def test_gan_server_serves_quantized_program(tmp_path):
    """The documented int8 deploy flow: export → load → GanServer with
    g_params=None adopts the program's precision and embedded
    weights."""
    from repro.serve.gan import GanServer
    cfg = GanConfig("dcgan", channel_scale=_SCALE, dtype="bf16")
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    spec = quantize_program(ProgramSpec.build(cfg, 2, "generator"), g)
    path = tmp_path / "prog.json"
    spec.save(path)
    prog = Program(ProgramSpec.load(path))
    srv = GanServer(GanConfig("dcgan", channel_scale=_SCALE), None,
                    batch_size=2, program=prog)
    assert srv.cfg.dtype == "bfloat16"    # adopted from the program
    imgs = srv.generate(3)
    assert imgs.shape == (3, 64, 64, 3)
    assert srv.samples_buffered == 1
    with pytest.raises(ValueError, match="quantized"):
        GanServer(cfg, None, batch_size=2)
