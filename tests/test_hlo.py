"""HLO cost parser: trip-count multipliers, dot/conv FLOPs, collectives."""

import textwrap

import pytest
from conftest import run_forced_devices

from repro.utils.hlo import _shape_bytes, analyze_hlo

SYNTH = textwrap.dedent("""
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={{0,1},{2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]{1,0}) tuple(%z, %a)
  %wl = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wl), index=1
}
""")


def test_trip_count_multiplies():
    c = analyze_hlo(SYNTH)
    # one dot of 2*8*16*16 flops × 5 trips
    assert c.flops == pytest.approx(2 * 8 * 16 * 16 * 5)
    # all-reduce of 8*16*4 bytes × 5 trips
    assert c.collective_bytes["all-reduce"] == pytest.approx(
        8 * 16 * 4 * 5)
    assert c.n_collectives["all-reduce"] == 5   # executions, not sites


def test_pod_crossing_detection():
    c_ici = analyze_hlo(SYNTH, pod_stride=2)   # {0,1},{2,3} pod-local
    assert c_ici.collective_dcn_bytes == 0
    c_dcn = analyze_hlo(SYNTH, pod_stride=3)   # {2,3} spans pods 0|1
    assert c_dcn.collective_dcn_bytes > 0


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("bf16[3]{0}") == 6
    assert _shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert _shape_bytes("pred[7]") == 7


@pytest.mark.slow
def test_parser_matches_unrolled_reference():
    """End-to-end: a scanned model parsed with trip counts must agree with
    the same model unrolled (run in a subprocess with 8 fake devices)."""
    run_forced_devices("""
        from repro.utils.hlo import analyze_hlo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        L, D, B = 6, 128, 16
        def f_scan(w, x):
            def body(x, wl):
                return jnp.tanh(x @ wl), None
            x, _ = jax.lax.scan(body, x, w)
            return x.sum()
        def f_unroll(w, x):
            for i in range(L):
                x = jnp.tanh(x @ w[i])
            return x.sum()
        w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        x = jax.ShapeDtypeStruct((B, D), jnp.float32)
        sh = (NamedSharding(mesh, P(None, None, 'model')),
              NamedSharding(mesh, P('data', None)))
        res = []
        for f in (f_scan, f_unroll):
            c = jax.jit(f, in_shardings=sh).lower(w, x).compile()
            res.append(analyze_hlo(c.as_text(), pod_stride=8).flops)
        assert abs(res[0] - res[1]) / res[1] < 0.05, res
        assert abs(res[1] - 2 * (B // 2) * D * (D // 4) * L) / res[1] < 0.05
        print("PASS")
    """)
