"""Mesh-sharded GAN programs: the frozen ``(data, model)`` mesh and
per-layer sharding in :class:`~repro.program.ProgramSpec`, shard_map
replay parity with single-device execution, sharded serving, and the
data-parallel train step.

Three tiers:

* plain in-process tests (spec round-trip, version gating, the
  footprint heuristic, oversized-mesh degradation on this process's
  single device);
* in-process multi-device tests, skipped unless the process already
  sees >= 8 devices — CI runs this file a second time under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to light
  these up;
* ``slow`` subprocess tests via ``conftest.run_forced_devices`` for
  the scenarios that need a forced device count regardless of how
  pytest was launched (all-model parity, exported-program serving,
  DP gradient parity).
"""

import numpy as np
import jax
import pytest
from conftest import run_forced_devices

from repro import obs as _obs
from repro.core.dataflow import COUT_SHARD_MIN_BYTES, choose_layer_sharding
from repro.launch.mesh import make_local_mesh
from repro.models.gan import GanConfig, init_gan
from repro.program import Program, ProgramSpec

SCALE = 0.0625


def _cfg(name="dcgan", **kw):
    return GanConfig(name=name, channel_scale=SCALE, **kw)


needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (run under the CI forced-device entry)")


# -- the footprint heuristic ------------------------------------------------

def test_choose_layer_sharding_heuristic():
    # no model axis, or Cout not divisible -> batch-only
    assert choose_layer_sharding((4, 4), 512, 512, 1) == "data"
    assert choose_layer_sharding((4, 4), 512, 511, 2) == "data"
    # 4*4*512*512*4 bytes == the 16 MiB threshold exactly -> shard
    assert 4 * 4 * 512 * 512 * 4 == COUT_SHARD_MIN_BYTES
    assert choose_layer_sharding((4, 4), 512, 512, 2) == "cout"
    # below the footprint threshold the all-gather isn't worth it
    assert choose_layer_sharding((4, 4), 8, 8, 2) == "data"
    # ...unless the threshold is overridden (tests force small configs)
    assert choose_layer_sharding((4, 4), 8, 8, 2, min_bytes=0) == "cout"


# -- spec: frozen mesh + sharding, JSON round-trip, version gating ----------

def test_spec_freezes_mesh_and_layer_sharding():
    spec = ProgramSpec.build(_cfg(), 8, mesh=(4, 2),
                             cout_shard_min_bytes=0)
    assert spec.mesh == (4, 2)
    shardings = [le.sharding for le in spec.layers]
    assert "cout" in shardings          # forced by min_bytes=0
    for le in spec.layers:
        if le.sharding == "cout":
            assert le.cout % 2 == 0
    assert "mesh=4x2" in spec.describe()
    assert "@cout" in spec.describe()


def test_cfg_mesh_is_build_default():
    spec = ProgramSpec.build(_cfg(mesh=(2, 1)), 4)
    assert spec.mesh == (2, 1)
    # explicit mesh=None overrides a config that carries one
    spec = ProgramSpec.build(_cfg(mesh=(2, 1)), 4, mesh=None)
    assert spec.mesh is None


def test_meshed_spec_json_round_trip(tmp_path):
    spec = ProgramSpec.build(_cfg(), 8, mesh=(4, 2),
                             cout_shard_min_bytes=0)
    assert ProgramSpec.from_json(spec.to_json()) == spec
    spec.save(tmp_path / "prog.json")
    assert ProgramSpec.load(tmp_path / "prog.json") == spec


def test_v1_document_loads_single_device():
    """Pre-mesh (version-1) program files still load: mesh defaults to
    None and every layer to batch-only sharding."""
    doc = ProgramSpec.build(_cfg(), 8).to_json()
    doc["version"] = 1
    del doc["mesh"]
    for layer in doc["layers"]:
        del layer["sharding"]
    loaded = ProgramSpec.from_json(doc)
    assert loaded.mesh is None
    assert all(le.sharding == "data" for le in loaded.layers)


def test_mesh_validation_rejects_corrupt_documents():
    spec = ProgramSpec.build(_cfg(), 8, mesh=(4, 2),
                             cout_shard_min_bytes=0)
    doc = spec.to_json()
    bad = dict(doc, mesh=[4])
    with pytest.raises(ValueError, match="mesh"):
        ProgramSpec.from_json(bad)
    # a Cout-sharded layer without a model axis must not load
    bad = dict(doc, mesh=None)
    with pytest.raises(ValueError, match="model axis"):
        ProgramSpec.from_json(bad)
    bad = dict(doc, layers=[dict(doc["layers"][0], sharding="weird")]
               + doc["layers"][1:])
    with pytest.raises(ValueError, match="sharding"):
        ProgramSpec.from_json(bad)


# -- local mesh construction ------------------------------------------------

def test_make_local_mesh_forms():
    n = jax.device_count()
    m = make_local_mesh()
    model = next((f for f in (4, 2) if n % f == 0), 1)
    assert dict(m.shape) == {"data": n // model, "model": model}
    # data-only convenience: pure DP, model axis of 1
    assert dict(make_local_mesh(data=1).shape) == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="does not divide"):
        make_local_mesh(model=2 * n)
    with pytest.raises(ValueError, match="needs"):
        make_local_mesh(data=n + 1, model=1)


@pytest.mark.slow
def test_make_local_mesh_odd_count_falls_back_to_model_1():
    """The documented no-argument fallback: an odd device count puts
    every device on the data axis (model=1) instead of crashing."""
    run_forced_devices("""
        from repro.launch.mesh import make_local_mesh
        m = make_local_mesh()
        assert dict(m.shape) == {"data": 7, "model": 1}, dict(m.shape)
        assert dict(make_local_mesh(data=7).shape) == \\
            {"data": 7, "model": 1}
        print("PASS")
    """, n_devices=7)


# -- oversized mesh degrades (the 1-device side of the export contract) ----

def test_oversized_mesh_degrades_with_warning(tmp_path):
    """An exported (4,2)-mesh program loaded on a 1-device box warns,
    runs single-device, and produces the same samples."""
    if jax.device_count() >= 8:
        pytest.skip("needs a device-starved process")
    cfg = _cfg()
    spec = ProgramSpec.build(cfg, 8, mesh=(4, 2), cout_shard_min_bytes=0)
    spec.save(tmp_path / "prog.json")
    loaded = ProgramSpec.load(tmp_path / "prog.json")
    before = _obs.counter("program.mesh_degraded").value
    with pytest.warns(RuntimeWarning, match="degrading"):
        prog = Program(loaded)
    assert _obs.counter("program.mesh_degraded").value == before + 1
    assert prog.mesh is None
    assert prog.device_count == 1
    assert prog.input_sharding is None
    assert prog.mesh_str == "1"
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.z_dim))
    ref = Program.build(cfg, 8, mesh=None)
    np.testing.assert_allclose(np.asarray(prog.apply(g, z)),
                               np.asarray(ref.apply(g, z)), atol=1e-6)


# -- in-process multi-device tests (CI forced-device matrix entry) ----------

@needs8
def test_sharded_forward_parity_inprocess():
    cfg = _cfg()
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.z_dim))
    plain = Program.build(cfg, 8, mesh=None)
    before = _obs.counter("program.sharded").value
    sharded = Program.build(cfg, 8, mesh=(4, 2), cout_shard_min_bytes=0)
    assert _obs.counter("program.sharded").value == before + 1
    assert sharded.device_count == 8
    assert sharded.mesh_str == "4x2"
    assert sharded.input_sharding is not None
    np.testing.assert_allclose(np.asarray(sharded.apply(g, z)),
                               np.asarray(plain.apply(g, z)), atol=1e-5)
    # batches must divide over the data axis
    with pytest.raises(ValueError, match="does not divide"):
        sharded.forward(g, z[:6])


@needs8
def test_sharded_server_stream_parity_inprocess():
    from repro.serve.gan import GanServer
    cfg = _cfg()
    g, _ = init_gan(cfg, jax.random.PRNGKey(0))
    ref = GanServer(cfg, g, batch_size=8, seed=7)
    prog = Program.build(cfg, 8, mesh=(4, 2), cout_shard_min_bytes=0,
                         differentiable=False)
    srv = GanServer(cfg, g, batch_size=8, seed=7, program=prog)
    np.testing.assert_allclose(srv.generate(12), ref.generate(12),
                               atol=1e-5)
    with pytest.raises(ValueError, match="data axis"):
        GanServer(cfg, g, batch_size=6, mesh=(4, 2))


# -- subprocess scenarios (forced 8 host CPU devices) -----------------------

@pytest.mark.slow
def test_all_table1_models_sharded_parity():
    """Every Table-I GAN generator produces allclose-identical samples
    sharded over a (4,2) mesh and on a single device (equal params and
    seeds); the dcgan discriminator rides along for the conv path."""
    run_forced_devices("""
        from repro.configs.gans import GAN_MODELS
        from repro.models.gan import GanConfig, init_gan
        from repro.program import Program
        n_cout = 0
        for name in sorted(GAN_MODELS):
            cfg = GanConfig(name=name, channel_scale=0.0625)
            g, d = init_gan(cfg, jax.random.PRNGKey(0))
            z = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.z_dim))
            plain = Program.build(cfg, 8, mesh=None)
            sharded = Program.build(cfg, 8, mesh=(4, 2),
                                    cout_shard_min_bytes=0)
            assert sharded.device_count == 8, name
            n_cout += sum(le.sharding == "cout"
                          for le in sharded.spec.layers)
            np.testing.assert_allclose(
                np.asarray(sharded.apply(g, z)),
                np.asarray(plain.apply(g, z)), atol=1e-5,
                err_msg=name)
        assert n_cout > 0, "no layer ever Cout-sharded"
        cfg = GanConfig(name="dcgan", channel_scale=0.0625)
        g, d = init_gan(cfg, jax.random.PRNGKey(0))
        img = Program.build(cfg, 8, mesh=None).apply(
            g, jax.random.normal(jax.random.PRNGKey(1), (8, cfg.z_dim)))
        p_d = Program.build(cfg, 8, "discriminator", mesh=None)
        s_d = Program.build(cfg, 8, "discriminator", mesh=(4, 2),
                            cout_shard_min_bytes=0)
        np.testing.assert_allclose(np.asarray(s_d.apply(d, img)),
                                   np.asarray(p_d.apply(d, img)),
                                   atol=1e-5)
        print("PASS")
    """)


@pytest.mark.slow
def test_exported_meshed_program_serves_identically(tmp_path):
    """The acceptance pin's 8-device side: a (4,2)-mesh program
    exported from this (single-device) process serves the bit-for-bit
    identical sample stream as a plain single-device server on 8
    forced devices."""
    cfg = _cfg()
    spec = ProgramSpec.build(cfg, 8, mesh=(4, 2), cout_shard_min_bytes=0)
    path = tmp_path / "dcgan_g.json"
    spec.save(path)
    run_forced_devices(f"""
        from repro import obs as _obs
        from repro.models.gan import GanConfig, init_gan
        from repro.program import Program, ProgramSpec
        from repro.serve.gan import GanServer
        cfg = GanConfig(name="dcgan", channel_scale=0.0625)
        g, _ = init_gan(cfg, jax.random.PRNGKey(0))
        prog = Program(ProgramSpec.load({str(path)!r}),
                       differentiable=False)
        assert prog.device_count == 8
        assert _obs.counter("program.sharded").value == 1
        srv = GanServer(cfg, g, batch_size=8, seed=7, program=prog)
        ref = GanServer(cfg, g, batch_size=8, seed=7)
        np.testing.assert_allclose(srv.generate(12), ref.generate(12),
                                   atol=1e-5)
        np.testing.assert_allclose(srv.generate(4), ref.generate(4),
                                   atol=1e-5)
        print("PASS")
    """)


@pytest.mark.slow
def test_engine_sharded_stream_parity():
    """The continuous-batching engine on a meshed program: identical
    stream to a plain engine at equal seed/buckets, bucket sizes
    validated against the data axis."""
    run_forced_devices("""
        from repro.models.gan import GanConfig, init_gan
        from repro.program import Program
        from repro.serve.gan_engine import GanEngine
        cfg = GanConfig(name="dcgan", channel_scale=0.0625)
        g, _ = init_gan(cfg, jax.random.PRNGKey(0))
        prog = Program.build(cfg, 8, mesh=(4, 2), cout_shard_min_bytes=0,
                             differentiable=False)
        eng = GanEngine(cfg, g, buckets=(4, 8), seed=3, program=prog)
        ref = GanEngine(cfg, g, buckets=(4, 8), seed=3)
        try:
            for n in (5, 7, 4):
                np.testing.assert_allclose(
                    eng.submit(n).result(30), ref.submit(n).result(30),
                    atol=1e-5)
        finally:
            eng.close(); ref.close()
        try:
            GanEngine(cfg, g, buckets=(2, 8), mesh=(4, 2), warmup=False)
            raise SystemExit("bucket 2 accepted on a (4,2) mesh")
        except ValueError as e:
            assert "divide" in str(e), e
        print("PASS")
    """)


@pytest.mark.slow
def test_dp_train_step_grad_parity():
    """Data-parallel training: the sharded step's losses and updated
    parameters match the single-device step (the shard_map transpose
    psums the weight cotangents — DP gradient reduction with no
    explicit pmean).  Float tolerance is relative: distributed
    reductions reassociate."""
    run_forced_devices("""
        from repro.models.gan import GanConfig, init_gan
        from repro.program import Program
        from repro.train.loop import make_gan_train_step
        cfg = GanConfig(name="dcgan", channel_scale=0.0625)
        gp, dp = init_gan(cfg, jax.random.PRNGKey(0))
        step_p, _ = make_gan_train_step(cfg, 8, mesh=None)
        step_s, (g_prog, _) = make_gan_train_step(cfg, 8, mesh=(4, 2))
        assert step_p.mesh is None and step_p.state_shardings is None
        assert step_s.mesh is not None
        z = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.z_dim))
        z2 = jax.random.normal(jax.random.PRNGKey(2), (8, cfg.z_dim))
        real = jnp.tanh(Program.build(cfg, 8, mesh=None).apply(gp, z2))
        batch = {"z": z, "real": np.asarray(real)}
        g_sh, d_sh = step_s.state_shardings
        state_s = (jax.device_put(gp, g_sh), jax.device_put(dp, d_sh))
        s1, m1 = step_p((gp, dp), batch)
        s2, m2 = step_s(state_s, batch)
        for k in m1:
            np.testing.assert_allclose(float(m1[k]), float(m2[k]),
                                       rtol=1e-4, err_msg=k)
        for a, b in zip(jax.tree_util.tree_leaves(s1),
                        jax.tree_util.tree_leaves(s2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        s2, m2 = step_s(s2, batch)    # placement stable across steps
        assert np.isfinite(float(m2["loss"]))
        print("PASS")
    """)
