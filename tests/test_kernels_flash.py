"""Pallas flash-attention kernel (HC4) vs the naive oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import naive_attention

CASES = [
    # (B, S, H, hd, causal, bq, bk)
    (2, 128, 3, 32, True, 32, 32),
    (2, 128, 3, 32, False, 32, 32),
    (1, 256, 2, 64, True, 64, 128),
    (1, 64, 4, 16, True, 64, 64),      # single q block
    (2, 96, 1, 8, True, 32, 48),       # uneven-ish blocks
]


@pytest.mark.parametrize("b,s,h,hd,causal,bq,bk", CASES)
def test_flash_kernel_vs_oracle(b, s, h, hd, causal, bq, bk):
    rng = np.random.default_rng(hash((b, s, h, hd, causal)) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref = naive_attention(q, k, v, pos, pos, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 2e-1)])
def test_flash_kernel_bf16(dtype, tol):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), dtype)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    ref = naive_attention(q, k, v, pos, pos, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_kernel_lowers_to_mosaic():
    from repro.compat import lower_as_mlir
    q = jnp.zeros((1, 512, 2, 128), jnp.float32)
    mlir = lower_as_mlir(
        lambda q, k, v: flash_attention_pallas(q, k, v, causal=True,
                                               interpret=False),
        q, q, q)
    assert len(str(mlir)) > 100
