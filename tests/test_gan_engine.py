"""Continuous-batching async serving engine (`serve.gan_engine`):
bit-parity with the sequential server under concurrent producers,
remainder-buffer accounting under interleaving, clean shutdown with
requests in flight, and the ahead-of-time bucket-set trace pin."""

import threading
import time

import numpy as np
import jax
import pytest

from repro.models.gan import GanConfig, init_gan
from repro.serve.gan import GanServer
from repro.serve.gan_engine import GanEngine, ServerClosed

SCALE = 0.03125


def _cfg(**kw):
    return GanConfig(name="dcgan", channel_scale=SCALE, **kw)


def _params(cfg=None):
    g, _ = init_gan(cfg or _cfg(), jax.random.PRNGKey(0))
    return g


def _reassemble(futures):
    """Concatenate answered futures in stream order (offset is set by
    the scheduler at allocation, so resolve before sorting)."""
    outs = [(f, f.result(30)) for f in futures]
    outs.sort(key=lambda pair: pair[0].offset)
    return np.concatenate([o for _, o in outs], axis=0)


# -- bit-parity with the sequential server ----------------------------------

def test_sequential_parity_with_gan_server():
    """A single-bucket engine produces the bit-identical stream to
    GanServer.generate at equal seeds, whatever the call chunking."""
    cfg, g = _cfg(), _params()
    ref = GanServer(cfg, g, batch_size=4, seed=5).generate(8)
    with GanEngine(cfg, g, buckets=(4,), seed=5) as eng:
        chunked = np.concatenate([eng.generate(3), eng.generate(3),
                                  eng.generate(2)])
    np.testing.assert_array_equal(chunked, ref)


@pytest.mark.parametrize("sizes", [(3, 3, 2), (1, 1, 1, 1, 4),
                                   (5, 2, 1)])
def test_concurrent_producers_bit_parity(sizes):
    """N producer threads submit concurrently; reassembling the
    responses by stream offset recovers the sequential server's exact
    sample stream — coalescing reorders nothing."""
    cfg, g = _cfg(), _params()
    total = sum(sizes)
    ref = GanServer(cfg, g, batch_size=4, seed=7).generate(total)
    with GanEngine(cfg, g, buckets=(4,), seed=7) as eng:
        futures, threads = [], []
        lock = threading.Lock()

        def produce(n):
            f = eng.submit(n)
            with lock:
                futures.append(f)

        for n in sizes:
            threads.append(threading.Thread(target=produce, args=(n,)))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        out = _reassemble(futures)
    np.testing.assert_array_equal(out, ref)


def test_engine_deterministic_across_runs():
    """Same seed + same sequential submission schedule → identical
    multi-bucket streams (bucket choice is demand-driven, and demand
    is deterministic when submissions are)."""
    cfg, g = _cfg(), _params()
    outs = []
    for _ in range(2):
        with GanEngine(cfg, g, buckets=(1, 2, 4), seed=11) as eng:
            outs.append(np.concatenate([eng.generate(3),
                                        eng.generate(4)]))
    np.testing.assert_array_equal(outs[0], outs[1])


# -- remainder-buffer accounting under interleaving -------------------------

def test_remainder_invariant_under_interleaving():
    """Whatever the thread interleaving and bucket choices, every
    generated sample is served, buffered, or discarded — and nothing
    is discarded in normal operation."""
    cfg, g = _cfg(), _params()
    sizes = [3, 1, 5, 2, 7, 1, 4, 3]
    with GanEngine(cfg, g, buckets=(1, 2, 4), seed=0) as eng:
        futures, threads = [], []
        lock = threading.Lock()

        def produce(n):
            f = eng.submit(n)
            with lock:
                futures.append(f)

        for n in sizes:
            threads.append(threading.Thread(target=produce, args=(n,)))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for f in futures:
            assert f.result(30).shape == (f.n, 64, 64, 3)
        assert eng.samples_served == sum(sizes)
        assert eng.samples_discarded == 0
        assert eng.samples_served + eng.samples_buffered + \
            eng.samples_discarded == \
            eng.samples_generated + eng.initial_spare
    # close() drains: the invariant still holds afterwards
    assert eng.samples_served + eng.samples_buffered + \
        eng.samples_discarded == eng.samples_generated


def test_spare_buffer_carries_across_requests():
    """A bucket's tail is buffered and serves the next request before
    any new compute (same accounting as the synchronous server)."""
    cfg, g = _cfg(), _params()
    with GanEngine(cfg, g, buckets=(4,), seed=3) as eng:
        eng.generate(3)
        assert (eng.samples_served, eng.samples_buffered) == (3, 1)
        assert eng.batches_served == 1
        eng.generate(1)          # served from the buffer, no new batch
        assert (eng.samples_served, eng.samples_buffered) == (4, 0)
        assert eng.batches_served == 1


# -- clean shutdown ---------------------------------------------------------

def test_close_drains_requests_in_flight():
    """close() answers every queued request before the scheduler
    exits — no future is left hanging or failed."""
    cfg, g = _cfg(), _params()
    eng = GanEngine(cfg, g, buckets=(2,), seed=0)
    futures = [eng.submit(3) for _ in range(4)]
    eng.close()                       # drain=True
    for f in futures:
        assert f.result(30).shape == (3, 64, 64, 3)
    assert eng.samples_served == 12


def test_close_without_drain_fails_unscheduled_requests():
    """close(drain=False): requests whose samples are already in
    flight are answered; the rest get ServerClosed — never a hang."""
    cfg, g = _cfg(), _params()
    release = threading.Event()
    eng = GanEngine(cfg, g, buckets=(2,), seed=0)
    # stall the scheduler inside a dispatch so requests pile up
    prog = eng.programs[2]
    real_apply = prog.apply

    def slow_apply(params, z):
        release.wait(10)
        return real_apply(params, z)

    prog.apply = slow_apply
    futures = [eng.submit(2) for _ in range(6)]
    time.sleep(0.05)                  # let the scheduler enter dispatch
    threading.Timer(0.05, release.set).start()
    eng.close(drain=False)
    answered = failed = 0
    for f in futures:
        err = f.exception(30)         # never hangs
        if err is None:
            assert f.result().shape == (2, 64, 64, 3)
            answered += 1
        else:
            assert isinstance(err, ServerClosed)
            failed += 1
    assert answered + failed == 6 and failed >= 1
    with pytest.raises(ServerClosed):
        eng.submit(1)


def test_scheduler_exception_fails_outstanding_requests():
    """An exception on the scheduler thread answers every outstanding
    future with that exception and closes the engine."""
    cfg, g = _cfg(), _params()
    eng = GanEngine(cfg, g, buckets=(2,), seed=0)

    def boom(params, z):
        raise RuntimeError("device on fire")

    eng.programs[2].apply = boom
    f = eng.submit(2)
    with pytest.raises(RuntimeError, match="device on fire"):
        f.result(30)
    with pytest.raises(ServerClosed):
        eng.submit(1)
    eng.close()


def test_context_manager_closes():
    cfg, g = _cfg(), _params()
    with GanEngine(cfg, g, buckets=(2,), seed=0) as eng:
        eng.generate(2)
    with pytest.raises(ServerClosed):
        eng.submit(1)


def test_backpressure_bounds_the_queue():
    """max_pending blocks submit while the queue is full; a bounded
    wait surfaces as TimeoutError instead of unbounded memory."""
    cfg, g = _cfg(), _params()
    release = threading.Event()
    eng = GanEngine(cfg, g, buckets=(2,), seed=0, max_pending=1)
    prog = eng.programs[2]
    real_apply = prog.apply

    def stalled_apply(p, z):
        release.wait(10)
        return real_apply(p, z)

    prog.apply = stalled_apply
    first = eng.submit(2)             # occupies the single queue slot
    time.sleep(0.05)
    with pytest.raises(TimeoutError):
        eng.submit(2, timeout=0.05)
    release.set()
    assert first.result(30).shape == (2, 64, 64, 3)
    eng.close()


# -- ahead-of-time bucket set ----------------------------------------------

def test_bucket_set_traces_exactly_once_per_shape():
    """The bucket set is compiled ahead of time from ONE spec: however
    many requests ride a bucket, its executable traces exactly once,
    and serving increments no retrace counter."""
    from repro import obs

    cfg, g = _cfg(), _params()
    retraces0 = obs.counter("program.retraces").value
    with GanEngine(cfg, g, buckets=(1, 2, 4), seed=0) as eng:
        for n in (1, 2, 4, 3, 7, 4, 1, 2):
            eng.generate(n)
        assert set(eng.programs) == {1, 2, 4}
        specs = {id(p.spec) for p in eng.programs.values()}
        assert len(specs) == 1        # one frozen spec, three wrappers
        for b, prog in eng.programs.items():
            assert prog.traces == 1, (b, prog.traces)
    assert obs.counter("program.retraces").value == retraces0


def test_bucket_choice_covers_demand():
    """Each batch runs the smallest bucket covering coalesced demand,
    the largest under overload — generated counts pin the choices."""
    cfg, g = _cfg(), _params()
    with GanEngine(cfg, g, buckets=(1, 2, 4), seed=0) as eng:
        eng.generate(1)
        assert eng.samples_generated == 1          # bucket 1
        eng.generate(2)
        assert eng.samples_generated == 3          # bucket 2
        eng.generate(7)     # overload → 4, then demand 3 → 4 again
        assert eng.samples_generated == 11
        assert eng.samples_buffered == 1


def test_exported_program_drives_engine():
    """ProgramSpec JSON → Program → GanEngine(program=...): the
    ship-a-tuned-program flow serves identically through the engine."""
    from repro.program import Program, ProgramSpec

    cfg, g = _cfg(), _params()
    ref_srv = GanServer(cfg, g, batch_size=4, seed=9)
    spec = ProgramSpec.from_json(ref_srv.program.spec.to_json())
    with GanEngine(cfg, g, buckets=(4,), seed=9,
                   program=Program(spec, differentiable=False)) as eng:
        np.testing.assert_array_equal(eng.generate(6),
                                      ref_srv.generate(6))


def test_engine_rejects_mismatched_program():
    from repro.program import Program, ProgramSpec

    cfg, g = _cfg(), _params()
    disc = Program(ProgramSpec.build(cfg, 4, "discriminator"))
    with pytest.raises(ValueError, match="generator"):
        GanEngine(cfg, g, buckets=(4,), program=disc)
    other = Program(ProgramSpec.build(
        GanConfig(name="dcgan", channel_scale=2 * SCALE), 4,
        "generator"))
    with pytest.raises(ValueError, match="different workload"):
        GanEngine(cfg, g, buckets=(4,), program=other)


def test_engine_rejects_bad_parameters():
    cfg, g = _cfg(), _params()
    with pytest.raises(ValueError, match="buckets"):
        GanEngine(cfg, g, buckets=())
    with pytest.raises(ValueError, match="buckets"):
        GanEngine(cfg, g, buckets=(0, 2))
    with pytest.raises(ValueError, match="pipeline_depth"):
        GanEngine(cfg, g, buckets=(2,), pipeline_depth=0)
    with pytest.raises(ValueError, match="max_pending"):
        GanEngine(cfg, g, buckets=(2,), max_pending=0)
    with GanEngine(cfg, g, buckets=(2,)) as eng:
        with pytest.raises(ValueError, match="positive"):
            eng.submit(0)


# -- observability ----------------------------------------------------------

def test_engine_metrics_and_request_spans():
    """The engine emits queue-depth gauge updates, a batch-occupancy
    histogram, per-request latency percentiles, and one cross-thread
    `engine.request` span per completed request."""
    from repro import obs

    cfg, g = _cfg(), _params()
    sink = obs.enable()
    try:
        with GanEngine(cfg, g, buckets=(4,), seed=0) as eng:
            for n in (3, 5, 4):
                eng.generate(n)
            labels = {"engine": eng.engine_id}
            h = obs.histogram("engine.request_us", **labels)
            assert h.count == 3
            assert h.percentile(50) > 0
            occ = obs.histogram("engine.batch_occupancy", **labels)
            assert occ.count == eng.batches_served
            assert obs.counter("engine.requests", **labels).value == 3
            assert obs.gauge("engine.queue_depth", **labels).value == 0
        spans = sink.spans("engine.request")
        assert len(spans) == 3
        assert sorted(s["attrs"]["n"] for s in spans) == [3, 4, 5]
        assert all(s["dur_us"] > 0 for s in spans)
        # offsets partition the stream contiguously
        offs = sorted((s["attrs"]["offset"], s["attrs"]["n"])
                      for s in spans)
        pos = 0
        for off, n in offs:
            assert off == pos
            pos += n
    finally:
        obs.disable()


# -- GanServer async façade -------------------------------------------------

def test_server_facade_mixed_sync_async_parity():
    """GanServer.submit hands the stream to an internal engine; mixing
    generate() and submit() keeps it bit-identical to a purely
    synchronous server at equal seeds."""
    cfg, g = _cfg(), _params()
    ref = GanServer(cfg, g, batch_size=4, seed=5).generate(12)
    with GanServer(cfg, g, batch_size=4, seed=5) as srv:
        parts = [srv.generate(3)]               # sync path (buffers 1)
        parts.append(srv.submit(5).result(30))  # façade takes over
        parts.append(srv.generate(4))           # delegated
        assert srv.samples_served == 12
        assert srv.batches_served == 3
        assert srv.samples_served + srv.samples_buffered + \
            srv.samples_discarded == srv.batches_served * 4
    np.testing.assert_array_equal(np.concatenate(parts), ref)


def test_server_close_without_submit_is_noop():
    cfg, g = _cfg(), _params()
    srv = GanServer(cfg, g, batch_size=2, seed=0)
    srv.close()                      # no engine yet — must not raise
    assert srv.generate(2).shape == (2, 64, 64, 3)
