"""GANAX polyphase tconv vs the zero-insertion definition and XLA."""

import string

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax import lax  # noqa: E402

from repro.core.scheduler import make_schedule  # noqa: E402
from repro.core.tconv import (  # noqa: E402
    tconv_ganax, tconv_output_shape, tconv_zero_insert, zero_insert)


def xla_ref(x, w, s, p):
    nd = x.ndim - 2
    pads = tuple((w.shape[i] - 1 - p[i],) * 2 for i in range(nd))
    letters = "".join(c for c in string.ascii_uppercase if c not in "NCIO")
    sp = letters[:nd]
    dn = lax.conv_dimension_numbers(
        x.shape, w.shape, ("N" + sp + "C", sp + "IO", "N" + sp + "C"))
    return lax.conv_general_dilated(
        x, jnp.flip(w, tuple(range(nd))), (1,) * nd, pads,
        lhs_dilation=s, dimension_numbers=dn)


CASES_2D = [
    ((2, 4, 4, 3), (5, 5, 3, 7), (2, 2), (2, 2)),
    ((1, 4, 4, 2), (4, 4, 2, 5), (2, 2), (1, 1)),
    ((1, 5, 3, 2), (3, 5, 2, 4), (3, 2), (1, 2)),
    ((2, 6, 6, 3), (3, 3, 3, 4), (1, 1), (1, 1)),
    ((1, 7, 1, 2), (5, 1, 2, 3), (2, 1), (2, 0)),
    ((1, 8, 8, 1), (2, 2, 1, 1), (2, 2), (0, 0)),
    ((3, 4, 4, 8), (4, 4, 8, 16), (4, 4), (0, 0)),
]

CASES_3D = [
    ((1, 4, 4, 4, 2), (4, 4, 4, 2, 3), (2, 2, 2), (1, 1, 1)),
    ((2, 3, 3, 3, 1), (3, 3, 3, 1, 2), (3, 3, 3), (0, 0, 0)),
]


@pytest.mark.parametrize("xs,ws,s,p", CASES_2D + CASES_3D)
def test_against_xla(xs, ws, s, p):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = xla_ref(x, w, s, p)
    for fn in (tconv_ganax, tconv_zero_insert):
        got = fn(x, w, s, p)
        assert got.shape == ref.shape == tconv_output_shape(xs, ws, s, p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 1e-1)])
def test_dtypes(dtype, tol):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 4, 4, 8)), dtype)
    w = jnp.asarray(rng.normal(size=(4, 4, 8, 8)), dtype)
    got = tconv_ganax(x, w, (2, 2), (1, 1))
    ref = tconv_zero_insert(x, w, (2, 2), (1, 1))
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 6), st.integers(1, 5), st.integers(1, 3),
       st.integers(0, 2), st.integers(1, 4), st.integers(1, 4))
def test_property_2d(n, k, s, p, cin, cout):
    p = min(p, k - 1)
    rng = np.random.default_rng(n * 1000 + k * 100 + s * 10 + p)
    x = jnp.asarray(rng.normal(size=(1, n, n, cin)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, k, cin, cout)), jnp.float32)
    got = tconv_ganax(x, w, (s, s), (p, p))
    ref = xla_ref(x, w, (s, s), (p, p))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-4, rtol=3e-4)


def test_zero_insert_structure():
    """The expanded input is zero exactly off the stride grid."""
    x = jnp.ones((1, 3, 3, 1))
    e = zero_insert(x, (2, 3))
    assert e.shape == (1, 5, 7, 1)
    dense = np.asarray(e[0, :, :, 0])
    mask = np.zeros_like(dense, bool)
    mask[::2, ::3] = True
    assert (dense[mask] == 1).all() and (dense[~mask] == 0).all()
    # inserted-zero fraction matches the schedule's accounting
    sched = make_schedule((3, 3), (2, 3), (2, 3), (0, 0))
    assert sched.inconsequential_fraction() > 0.5


def test_gradients_match():
    """Both dataflows are differentiable and agree on gradients."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 4, 4, 2)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4, 2, 3)), jnp.float32)

    def loss(fn, x, w):
        return jnp.sum(jnp.square(fn(x, w, (2, 2), (1, 1))))

    g1 = jax.grad(lambda w: loss(tconv_ganax, x, w))(w)
    g2 = jax.grad(lambda w: loss(tconv_zero_insert, x, w))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-3, rtol=1e-3)
