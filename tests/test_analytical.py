"""Analytical model reproduces the paper's evaluation (Figs. 1, 8, 11)."""

import numpy as np
import pytest

from repro.configs.gans import GAN_MODELS
from repro.core.analytical import (AcceleratorConfig, ConvLayer,
                                   analyze_layer, analyze_model)


@pytest.fixture(scope="module")
def reports():
    return {name: analyze_model(name, g, d)
            for name, (g, d) in GAN_MODELS.items()}


def test_fig1_inconsequential_fractions(reports):
    """Stride-2 2-D tconvs waste ~75% of MACs, stride-2 3-D ~87.5%;
    MAGAN (stride-1 heavy) is the lowest — matches paper Fig. 1 ordering."""
    fracs = {}
    for name, (g, _) in GAN_MODELS.items():
        tconv = [l for l in g if l.transposed]
        reps = [analyze_layer(l) for l in tconv]
        t = sum(r.total_macs for r in reps)
        c = sum(r.consequential_macs for r in reps)
        fracs[name] = 1 - c / t
    assert fracs["3dgan"] > 0.85
    assert 0.70 < fracs["dcgan"] < 0.78
    assert fracs["magan"] == min(fracs.values())
    assert np.mean(list(fracs.values())) > 0.60   # paper: "more than 60%"


def test_fig8_speedups(reports):
    """Paper: 3.6× mean speedup, 3.1× mean energy; 3D-GAN highest (6.1×),
    MAGAN lowest (1.3×).  The reimplemented model must land in the same
    band and preserve the ordering."""
    sp = {n: r.gen_speedup for n, r in reports.items()}
    en = {n: r.gen_energy_reduction for n, r in reports.items()}
    assert sp["3dgan"] == max(sp.values()) and sp["3dgan"] > 5.0
    assert sp["magan"] == min(sp.values()) and sp["magan"] < 1.6
    assert 2.5 < np.mean(list(sp.values())) < 4.5    # paper 3.6
    assert 2.2 < np.mean(list(en.values())) < 4.0    # paper 3.1
    for n in sp:
        assert sp[n] >= 1.0 - 1e-9 and en[n] >= 1.0 - 1e-9


def test_fig11_utilization(reports):
    """GANAX PE utilization ≈ 90% (paper); EYERISS collapses on
    generative models."""
    for name, r in reports.items():
        u_g = r.utilization("ganax")
        u_b = r.utilization("baseline")
        assert u_g > 0.6, (name, u_g)
        assert u_g > u_b - 1e-9
    # heavy-zero models: baseline utilization is low
    assert reports["3dgan"].utilization("baseline") < 0.3


def test_discriminators_unaffected(reports):
    """Paper claim: no regression on conventional-conv models — baseline
    and GANAX cycles are identical on discriminator layers."""
    for name, r in reports.items():
        for lr in r.discriminator:
            assert lr.cycles_ganax == pytest.approx(lr.cycles_baseline)
            assert lr.speedup == pytest.approx(1.0)


def test_energy_breakdown_components(reports):
    r = reports["dcgan"]
    e = r.energy_breakdown("ganax")
    assert set(e) == {"rf", "pe", "inter_pe", "gbuf", "dram"}
    assert all(v > 0 for v in e.values())
    # GANAX reduces every component (paper Fig. 10)
    eb = r.energy_breakdown("baseline")
    for k in e:
        assert e[k] <= eb[k] * (1 + 1e-9), k


def test_conv_layer_out_spatial():
    l = ConvLayer("c", (64, 64), (4, 4), (2, 2), (1, 1), 3, 8,
                  transposed=False)
    assert l.conv_out_spatial() == (32, 32)


def test_accel_config():
    acc = AcceleratorConfig()
    assert acc.n_pes == 256   # paper's 16 PVs × 16 PEs
