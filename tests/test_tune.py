"""Autotuning planner (`repro.tune`): candidate enumeration, measured
plans, JSON persistence (round-trip / corrupt / stale / warm-file
zero-measurement contract), and `DataflowPolicy(backend="auto")`
dispatch."""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import DataflowPolicy, available_backends, tconv
from repro.tune import (Candidate, Plan, PlanKey, Planner,
                        enumerate_candidates, plan_key_for_op,
                        set_planner, warm_gan_plans)

KEY = PlanKey(kind="tconv", batch=1, in_spatial=(4, 4), kernel=(4, 4),
              strides=(2, 2), paddings=(1, 1), cin=4, cout=6,
              dtype="float32", platform="cpu")


@pytest.fixture(autouse=True)
def _isolated_planner():
    """Tests must not leak a process-wide planner into each other."""
    set_planner(None)
    yield
    set_planner(None)


def _xw(key=KEY):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(key.batch, *key.in_spatial, key.cin)),
                    jnp.float32)
    w = jnp.asarray(rng.normal(size=(*key.kernel, key.cin, key.cout)),
                    jnp.float32)
    return x, w


# ---------------------------------------------------------------------------
# Candidate enumeration.
# ---------------------------------------------------------------------------

def test_candidates_cpu_pool_excludes_pallas():
    """On CPU the measured pool is the fast pure-JAX paths; compiled
    pallas-tpu can't run and interpret mode is never a sensible plan."""
    cands = enumerate_candidates(KEY)
    assert [c.backend for c in cands] == ["polyphase", "zero-insert"]
    assert all(c.blocks is None for c in cands)


def test_candidates_pallas_blocks_valid_divisors():
    key = PlanKey(kind="tconv", batch=1, in_spatial=(8, 8), kernel=(4, 4),
                  strides=(2, 2), paddings=(1, 1), cin=128, cout=64,
                  dtype="float32", platform="cpu")
    cands = enumerate_candidates(key, backends=["pallas-interpret"])
    assert cands[0].blocks is not None       # default blocks come first
    qy = 8  # ceil(16/2): phase-plane height of the 8→16 upsample
    for c in cands:
        bqy, bci, bco = c.blocks
        assert qy % bqy == 0 and 128 % bci == 0 and 64 % bco == 0
    assert len({c.blocks for c in cands}) == len(cands) > 1


def test_candidates_respect_rank_support():
    """1-D layers stay outside the kernel's rank coverage: the Pallas
    backends must not appear in the candidate pool."""
    key1d = PlanKey(kind="tconv", batch=1, in_spatial=(5,), kernel=(4,),
                    strides=(2,), paddings=(1,), cin=2, cout=3,
                    dtype="float32", platform="cpu")
    cands = enumerate_candidates(key1d,
                                 backends=["pallas-interpret", "polyphase"])
    assert [c.backend for c in cands] == ["polyphase"]


def test_candidates_3d_blocks_valid_divisors():
    """The volumetric sweep: 3-D Pallas candidates carry
    (block_qz, block_qy, block_cin, block_cout) quadruples whose leading
    extents divide the phase-plane grid."""
    key3d = PlanKey(kind="tconv", batch=1, in_spatial=(8, 8, 8),
                    kernel=(4, 4, 4), strides=(2, 2, 2),
                    paddings=(1, 1, 1), cin=64, cout=32,
                    dtype="float32", platform="cpu")
    cands = enumerate_candidates(key3d, backends=["pallas-interpret"])
    assert cands[0].blocks is not None       # default blocks come first
    qz = qy = 8  # ceil(16/2): phase-plane extents of the 8→16 upsample
    for c in cands:
        bqz, bqy, bci, bco = c.blocks
        assert qz % bqz == 0 and qy % bqy == 0
        assert 64 % bci == 0 and 32 % bco == 0
    assert len({c.blocks for c in cands}) == len(cands) > 1


# ---------------------------------------------------------------------------
# Plan-cache persistence.
# ---------------------------------------------------------------------------

def test_plan_file_round_trip(tmp_path):
    path = tmp_path / "plans.json"
    p1 = Planner(path, repeats=2)
    plan = p1.plan(KEY)
    assert plan.source == "measured" and p1.measurements > 0
    assert path.exists()

    p2 = Planner(path)
    assert len(p2) == 1
    assert p2.lookup(KEY) == plan
    # warm file → plan() answers with zero measurements
    assert p2.plan(KEY) == plan
    assert p2.measurements == 0


def test_corrupt_plan_file_falls_back(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text("{not json")
    p = Planner(path)
    assert p.load_error is not None
    assert len(p) == 0
    assert p.lookup(KEY) is None            # heuristic territory, no crash
    # the planner still tunes and can overwrite the corrupt file
    p.repeats = 1
    p.plan(KEY)
    assert json.loads(path.read_text())["version"] == 1


def test_stale_entries_dropped(tmp_path):
    path = tmp_path / "plans.json"
    good = {"key": KEY.to_json(),
            "plan": Plan(backend="zero-insert").to_json()}
    stale = {"key": KEY.to_json(),
             "plan": {"backend": "systolic-array-9000", "blocks": None}}
    path.write_text(json.dumps({"version": 1, "plans": [stale, good]}))
    p = Planner(path)
    assert p.stale_dropped == 1
    assert p.lookup(KEY).backend == "zero-insert"


def test_pre_epilogue_plan_file_loads(tmp_path):
    """Plan files written before the fused-epilogue refactor lack the
    bias/activation/leaky_slope key fields: they must load (missing
    epilogue == identity), not crash or be dropped as stale."""
    old_key = KEY.to_json()
    for f in ("bias", "activation", "leaky_slope"):
        del old_key[f]
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({
        "version": 1,
        "plans": [{"key": old_key,
                   "plan": {"backend": "zero-insert", "blocks": None}}]}))
    p = Planner(path)
    assert p.load_error is None and p.stale_dropped == 0
    assert p.lookup(KEY).backend == "zero-insert"   # identity-epilogue key
    # an epilogue-carrying key is a *different* workload: no false hit
    import dataclasses
    fused = dataclasses.replace(KEY, bias=True, activation="relu")
    assert p.lookup(fused) is None
    # unknown fields still make an entry stale (dropped, not fatal)
    bad_key = dict(KEY.to_json(), systolic=True)
    path.write_text(json.dumps({
        "version": 1,
        "plans": [{"key": bad_key,
                   "plan": {"backend": "zero-insert", "blocks": None}}]}))
    p2 = Planner(path)
    assert p2.stale_dropped == 1 and len(p2) == 0


def test_epilogue_key_round_trips(tmp_path):
    """Epilogue-carrying plan keys survive the JSON plan file."""
    import dataclasses
    fused = dataclasses.replace(KEY, bias=True, activation="leaky_relu",
                                leaky_slope=0.2)
    assert PlanKey.from_json(fused.to_json()) == fused
    path = tmp_path / "plans.json"
    p1 = Planner(path)
    p1.put(fused, Plan(backend="polyphase"))
    p2 = Planner(path)
    assert p2.lookup(fused).backend == "polyphase"
    assert p2.lookup(KEY) is None


def test_wrong_version_is_stale(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({"version": 999, "plans": []}))
    p = Planner(path)
    assert p.load_error is not None and "version" in p.load_error


def test_second_process_warm_file_zero_measurements(tmp_path):
    """The acceptance contract end-to-end: a fresh *process* starting
    from the persisted plan file performs zero measurements."""
    path = tmp_path / "plans.json"
    Planner(path, repeats=1).plan(KEY)
    key_json = json.dumps(KEY.to_json())
    code = f"""
import json
from repro.tune import Planner, PlanKey
key = PlanKey.from_json(json.loads({key_json!r}))
p = Planner({str(path)!r})
plan = p.plan(key)
assert plan.source == "measured", plan
assert p.measurements == 0, p.measurements
print("MEASUREMENTS", p.measurements)
"""
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               PYTHONPATH=f"{root / 'src'}:{os.environ.get('PYTHONPATH', '')}",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         cwd=str(root), env=env)
    assert out.returncode == 0, out.stderr
    assert "MEASUREMENTS 0" in out.stdout


def test_time_interleaved_reduce_modes(monkeypatch):
    """``reduce="min"`` takes the per-thunk noise floor, ``"median"``
    the representative cost; an unknown reducer is rejected.  The clock
    is stubbed so the samples are exact: with 2 thunks and the rotated
    round-robin, thunk0 times [10, 30, 20] and thunk1 [100, 200, 300]."""
    from repro.tune import measure as m

    clock = [0, 10, 10, 110, 110, 310, 310, 340, 340, 360, 360, 660]
    ticks = iter(clock)
    monkeypatch.setattr(m.time, "perf_counter", lambda: next(ticks))
    thunks = [lambda: 1, lambda: 2]
    assert m.time_interleaved(thunks, warmup=0, repeats=3) == [20.0, 200.0]
    ticks = iter(clock)
    assert m.time_interleaved(thunks, warmup=0, repeats=3,
                              reduce="min") == [10.0, 100.0]
    with pytest.raises(ValueError):
        m.time_interleaved(thunks, reduce="mean")


# ---------------------------------------------------------------------------
# Measured tuning behavior.
# ---------------------------------------------------------------------------

def test_tune_prefers_heuristic_within_margin(monkeypatch):
    """A within-noise 'win' must not flip the plan off the heuristic."""
    p = Planner(margin=0.1)
    fake = {Candidate("polyphase"): 1.00e-3,
            Candidate("zero-insert"): 0.95e-3}   # 5% faster: inside margin
    monkeypatch.setattr(p, "measure_candidates", lambda key, backends=None:
                        dict(fake))
    assert p.tune(KEY).backend == "polyphase"
    fake[Candidate("zero-insert")] = 0.5e-3      # 50% faster: clear win
    assert p.tune(KEY).backend == "zero-insert"


def test_tune_all_candidates_failing_degrades_to_heuristic(monkeypatch):
    p = Planner()
    monkeypatch.setattr(p, "measure_candidates",
                        lambda key, backends=None: {})
    plan = p.tune(KEY)
    assert plan.source == "heuristic"
    assert plan.backend == DataflowPolicy().resolve(2)


# ---------------------------------------------------------------------------
# backend="auto" dispatch.
# ---------------------------------------------------------------------------

AUTO_BACKENDS = [b for b in available_backends() if b != "pallas-tpu"]


@pytest.mark.parametrize("backend", AUTO_BACKENDS)
def test_auto_matches_every_concrete_backend(backend):
    """Acceptance: auto dispatch executing a plan pinned to each concrete
    backend reproduces that backend's numerics exactly."""
    x, w = _xw()
    planner = set_planner(Planner())
    planner.put(KEY, Plan(backend=backend, blocks=None))
    auto = tconv(x, w, KEY.strides, KEY.paddings,
                 policy=DataflowPolicy(backend="auto"))
    pinned = tconv(x, w, KEY.strides, KEY.paddings,
                   policy=DataflowPolicy(backend=backend))
    np.testing.assert_allclose(np.asarray(auto), np.asarray(pinned),
                               atol=1e-5, rtol=1e-5)
    assert planner.hits >= 1 and planner.measurements == 0


def test_auto_uses_tuned_pallas_blocks():
    """An auto plan carrying Pallas block shapes reaches the kernel (and
    stays differentiable through the custom VJP)."""
    key = PlanKey(kind="tconv", batch=1, in_spatial=(4, 4), kernel=(4, 4),
                  strides=(2, 2), paddings=(1, 1), cin=4, cout=6,
                  dtype="float32", platform="cpu")
    planner = set_planner(Planner())
    planner.put(key, Plan(backend="pallas-interpret", blocks=(2, 2, 3)))
    x, w = _xw(key)
    policy = DataflowPolicy(backend="auto")

    def loss(x, w):
        return jnp.sum(tconv(x, w, key.strides, key.paddings,
                             policy=policy) ** 2)

    ref = tconv(x, w, key.strides, key.paddings,
                policy=DataflowPolicy(backend="zero-insert"))
    np.testing.assert_allclose(
        np.asarray(tconv(x, w, key.strides, key.paddings, policy=policy)),
        np.asarray(ref), atol=1e-4, rtol=1e-4)
    gx = jax.grad(loss)(x, w)
    assert gx.shape == x.shape


def test_auto_plan_miss_falls_back_to_heuristic():
    x, w = _xw()
    planner = set_planner(Planner())
    out = tconv(x, w, KEY.strides, KEY.paddings,
                policy=DataflowPolicy(backend="auto"))
    ref = tconv(x, w, KEY.strides, KEY.paddings)   # heuristic policy
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert planner.lookups == 1 and planner.hits == 0
    assert planner.measurements == 0               # dispatch never measures


def test_auto_stale_plan_backend_falls_back():
    """A plan naming a backend that can't run this rank degrades to the
    heuristic instead of raising (stale plan files must never break
    dispatch).  1-D is the rank the kernel doesn't cover."""
    key1d = PlanKey(kind="tconv", batch=1, in_spatial=(3,), kernel=(2,),
                    strides=(2,), paddings=(0,), cin=2, cout=3,
                    dtype="float32", platform="cpu")
    planner = set_planner(Planner())
    planner.put(key1d, Plan(backend="pallas-interpret"))  # 2-D/3-D only
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 3, 2)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 2, 3)), jnp.float32)
    out = tconv(x, w, key1d.strides, key1d.paddings,
                policy=DataflowPolicy(backend="auto"))
    assert out.shape == (1, 6, 3)


def test_auto_uses_tuned_3d_pallas_blocks():
    """A volumetric plan carrying a (qz, qy, cin, cout) quadruple reaches
    the 3-D kernel through auto dispatch, survives a JSON round-trip, and
    stays differentiable."""
    key3d = PlanKey(kind="tconv", batch=1, in_spatial=(3, 3, 3),
                    kernel=(4, 4, 4), strides=(2, 2, 2),
                    paddings=(1, 1, 1), cin=2, cout=3,
                    dtype="float32", platform="cpu")
    plan = Plan(backend="pallas-interpret", blocks=(1, 3, 2, 3))
    assert Plan.from_json(plan.to_json()) == plan
    planner = set_planner(Planner())
    planner.put(key3d, plan)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, 3, 3, 3, 2)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 4, 4, 2, 3)), jnp.float32)
    policy = DataflowPolicy(backend="auto")
    ref = tconv(x, w, key3d.strides, key3d.paddings,
                policy=DataflowPolicy(backend="zero-insert"))
    np.testing.assert_allclose(
        np.asarray(tconv(x, w, key3d.strides, key3d.paddings,
                         policy=policy)),
        np.asarray(ref), atol=1e-4, rtol=1e-4)
    gx = jax.grad(lambda x: jnp.sum(tconv(
        x, w, key3d.strides, key3d.paddings, policy=policy) ** 2))(x)
    assert gx.shape == x.shape and planner.hits >= 1


def test_auto_stale_plan_blocks_fall_back():
    """Block shapes that no longer divide the geometry (hand-edited or
    version-skewed plan file) keep the planned backend but drop to its
    default tiles — never a ValueError from inside a trace."""
    planner = set_planner(Planner())
    planner.put(KEY, Plan(backend="pallas-interpret", blocks=(3, 8, 16)))
    x, w = _xw()
    out = jax.jit(lambda x, w: tconv(
        x, w, KEY.strides, KEY.paddings,
        policy=DataflowPolicy(backend="auto")))(x, w)
    ref = tconv(x, w, KEY.strides, KEY.paddings,
                policy=DataflowPolicy(backend="pallas-interpret"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_auto_interpret_contradiction_raises():
    with pytest.raises(ValueError, match="auto"):
        DataflowPolicy(backend="auto", interpret=True).resolve(2)


def test_plan_key_for_op_matches_layer_key():
    """Dispatch-built keys (from array shapes) and topology-built keys
    (from ConvLayer geometry) must agree, or plans warmed ahead of time
    would never be found at dispatch."""
    x, w = _xw()
    key = plan_key_for_op("tconv", x, w, KEY.strides, KEY.paddings)
    assert key == KEY  # conftest pins JAX_PLATFORMS=cpu


def test_warm_gan_plans_covers_all_layers():
    from repro.models.gan import GanConfig
    cfg = GanConfig(name="dcgan", channel_scale=0.03125)
    planner = Planner(repeats=1)
    plans = warm_gan_plans(cfg, batch=2, planner=planner)
    g_layers, d_layers = cfg.layers
    assert len(plans) == len(g_layers) + len(d_layers)
    assert all(p.source == "measured" for p in plans.values())
    # warming again is free: every geometry already has a plan
    before = planner.measurements
    warm_gan_plans(cfg, batch=2, planner=planner)
    assert planner.measurements == before
