"""Differentiability of the unified dataflow dispatch (`core.dataflow`).

The custom VJP must match XLA's own autodiff through the lax-built
reference (``tconv_zero_insert`` / ``conv_ref``) on every backend,
including the Pallas kernel — which has no autodiff rule of its own, so
these tests are what certifies ``GanConfig(use_pallas=True)`` as
trainable.  Also locks the μop compilation cache contract: repeated
identical layer geometry runs the scheduler once.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import (DataflowPolicy, SecondOrderNotImplemented,
                                 compile_uops, conv, tconv,
                                 uop_cache_clear, uop_cache_info)
from repro.core.tconv import tconv_zero_insert
from repro.kernels.ref import conv_ref

BACKENDS = ["zero-insert", "polyphase", "pallas-interpret", "pallas"]

# (x_shape, w_shape, strides, pads) — strides {1,2,3} and kernel<stride.
TCONV_CASES = [
    ((2, 5, 5, 2), (3, 3, 2, 4), (1, 1), (1, 1)),
    ((1, 4, 4, 2), (4, 4, 2, 3), (2, 2), (1, 1)),
    ((1, 5, 3, 2), (3, 5, 2, 4), (3, 2), (1, 2)),
    ((1, 3, 3, 2), (2, 2, 2, 3), (3, 3), (0, 0)),   # kernel < stride
]

CONV_CASES = [
    ((2, 6, 6, 3), (3, 3, 3, 4), (1, 1), (1, 1)),
    ((1, 8, 8, 2), (3, 3, 2, 4), (2, 2), (1, 1)),   # stride tail unread
    ((1, 7, 7, 3), (3, 3, 3, 5), (3, 3), (0, 0)),
    ((1, 9, 9, 2), (5, 5, 2, 3), (2, 2), (2, 2)),
]


def _grads(fn, x, w, cot):
    def loss(x, w):
        return jnp.sum(fn(x, w) * cot)
    return jax.grad(loss, argnums=(0, 1))(x, w)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("xs,ws,s,p", TCONV_CASES)
def test_tconv_grad_parity(backend, xs, ws, s, p):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = tconv_zero_insert(x, w, s, p)
    cot = jnp.asarray(rng.normal(size=ref.shape), jnp.float32)
    gx_ref, gw_ref = _grads(lambda x, w: tconv_zero_insert(x, w, s, p),
                            x, w, cot)
    policy = DataflowPolicy(backend=backend)
    out = tconv(x, w, s, p, policy=policy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    gx, gw = _grads(lambda x, w: tconv(x, w, s, p, policy=policy),
                    x, w, cot)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("xs,ws,s,p", CONV_CASES)
def test_conv_grad_parity(backend, xs, ws, s, p):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = conv_ref(x, w, s, p)
    cot = jnp.asarray(rng.normal(size=ref.shape), jnp.float32)
    gx_ref, gw_ref = _grads(lambda x, w: conv_ref(x, w, s, p), x, w, cot)
    policy = DataflowPolicy(backend=backend)
    out = conv(x, w, s, p, policy=policy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    gx, gw = _grads(lambda x, w: conv(x, w, s, p, policy=policy),
                    x, w, cot)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                               atol=1e-4, rtol=1e-4)


# 3-D (volumetric) cases — strides {1,2,3}, mixed strides, kernel<stride.
TCONV3D_CASES = [
    ((1, 3, 3, 3, 2), (3, 3, 3, 2, 3), (1, 1, 1), (1, 1, 1)),
    ((1, 3, 3, 3, 2), (4, 4, 4, 2, 3), (2, 2, 2), (1, 1, 1)),
    ((1, 3, 2, 3, 2), (3, 4, 3, 2, 2), (3, 2, 1), (1, 1, 0)),
    ((1, 2, 2, 2, 2), (2, 2, 2, 2, 3), (3, 3, 3), (0, 0, 0)),
]

CONV3D_CASES = [
    ((1, 5, 5, 5, 2), (3, 3, 3, 2, 3), (1, 1, 1), (1, 1, 1)),
    ((1, 6, 6, 6, 2), (4, 4, 4, 2, 3), (2, 2, 2), (1, 1, 1)),
    ((1, 7, 5, 7, 2), (3, 3, 3, 2, 2), (3, 2, 3), (0, 1, 0)),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("xs,ws,s,p", TCONV3D_CASES)
def test_tconv_grad_parity_3d(backend, xs, ws, s, p):
    """Volumetric grad parity: the 3-D Pallas kernel's custom VJP (and
    the pure-JAX backends) must match XLA's autodiff through the
    zero-insertion reference — the 3D-GAN training path."""
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = tconv_zero_insert(x, w, s, p)
    cot = jnp.asarray(rng.normal(size=ref.shape), jnp.float32)
    gx_ref, gw_ref = _grads(lambda x, w: tconv_zero_insert(x, w, s, p),
                            x, w, cot)
    policy = DataflowPolicy(backend=backend)
    out = tconv(x, w, s, p, policy=policy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    gx, gw = _grads(lambda x, w: tconv(x, w, s, p, policy=policy),
                    x, w, cot)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("xs,ws,s,p", CONV3D_CASES)
def test_conv_grad_parity_3d(backend, xs, ws, s, p):
    rng = np.random.default_rng(hash((xs, ws, s, p)) % 2**31)
    x = jnp.asarray(rng.normal(size=xs), jnp.float32)
    w = jnp.asarray(rng.normal(size=ws), jnp.float32)
    ref = conv_ref(x, w, s, p)
    cot = jnp.asarray(rng.normal(size=ref.shape), jnp.float32)
    gx_ref, gw_ref = _grads(lambda x, w: conv_ref(x, w, s, p), x, w, cot)
    policy = DataflowPolicy(backend=backend)
    out = conv(x, w, s, p, policy=policy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    gx, gw = _grads(lambda x, w: conv(x, w, s, p, policy=policy),
                    x, w, cot)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                               atol=1e-4, rtol=1e-4)


def test_3dgan_layers_pallas_forward_and_vjp_parity():
    """Acceptance: every 3D-GAN generator/discriminator layer geometry
    (channel-scaled for CPU) runs the volumetric Pallas kernel in
    interpret mode with forward and VJP matching the polyphase and
    zero-insert references."""
    from repro.configs.gans import gan_layers

    g_layers, d_layers = gan_layers("3dgan")
    scale = 1 / 16
    interp = DataflowPolicy(backend="pallas-interpret")
    poly = DataflowPolicy(backend="polyphase")
    for layer in g_layers + d_layers:
        cin = max(1, int(layer.cin * scale))
        cout = max(1, int(layer.cout * scale))
        rng = np.random.default_rng(layer.cin * 31 + layer.cout)
        x = jnp.asarray(rng.normal(size=(1, *layer.in_spatial, cin)),
                        jnp.float32)
        w = jnp.asarray(rng.normal(size=(*layer.kernel, cin, cout)),
                        jnp.float32)
        s, p = layer.strides, layer.paddings
        if layer.transposed:
            op, ref_fn = tconv, tconv_zero_insert
        else:
            op, ref_fn = conv, conv_ref
        ref = ref_fn(x, w, s, p)
        got = op(x, w, s, p, policy=interp)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-3, rtol=1e-3,
                                   err_msg=f"forward {layer.name}")
        np.testing.assert_allclose(
            np.asarray(op(x, w, s, p, policy=poly)), np.asarray(ref),
            atol=1e-3, rtol=1e-3, err_msg=f"polyphase {layer.name}")
        cot = jnp.asarray(rng.normal(size=ref.shape), jnp.float32)
        gx_ref, gw_ref = _grads(lambda x, w: ref_fn(x, w, s, p), x, w, cot)
        gx, gw = _grads(lambda x, w: op(x, w, s, p, policy=interp),
                        x, w, cot)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                                   atol=1e-3, rtol=1e-3,
                                   err_msg=f"dx {layer.name}")
        np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                                   atol=1e-3, rtol=1e-3,
                                   err_msg=f"dw {layer.name}")


@pytest.mark.parametrize("op", [tconv, conv])
def test_second_order_autodiff_raises_clearly(op):
    """The kernel backends' custom VJP defines one backward pass;
    grad-of-grad used to be silently wrong — it must raise with
    guidance instead (ROADMAP open item)."""
    policy = DataflowPolicy(backend="pallas-interpret")
    x = jnp.ones((1, 4, 4, 2))
    w = jnp.ones((3, 3, 2, 2))

    def loss(x):
        return jnp.sum(op(x, w, (2, 2), (1, 1), policy=policy) ** 2)

    jax.grad(loss)(x)  # first order stays supported
    with pytest.raises(SecondOrderNotImplemented,
                       match="pure-JAX backend"):
        jax.grad(lambda x: jnp.sum(jax.grad(loss)(x)))(x)


def test_second_order_supported_on_pure_jax_backends():
    """Higher-order autodiff keeps working where XLA natively provides
    it, and matches across the two zero-free formulations."""
    x = jnp.ones((1, 3, 3, 2)) * 0.5
    w = jnp.ones((2, 2, 2, 2)) * 0.25

    def loss(policy):
        def f(x):
            return jnp.sum(tconv(x, w, (2, 2), (0, 0), policy=policy) ** 3)
        return f

    g2 = {b: jax.grad(lambda x: jnp.sum(jax.grad(loss(
        DataflowPolicy(backend=b)))(x)))(x)
        for b in ("polyphase", "zero-insert")}
    np.testing.assert_allclose(np.asarray(g2["polyphase"]),
                               np.asarray(g2["zero-insert"]),
                               atol=1e-4, rtol=1e-4)


def test_uop_cache_hit_on_repeated_geometry():
    """make_schedule runs once for repeated identical layer geometry."""
    uop_cache_clear()
    x = jnp.ones((1, 4, 4, 2))
    w = jnp.ones((4, 4, 2, 3))
    policy = DataflowPolicy(backend="polyphase")
    tconv(x, w, (2, 2), (1, 1), policy=policy)
    first = uop_cache_info()
    assert first["misses"] == 1
    for _ in range(3):
        tconv(x, w, (2, 2), (1, 1), policy=policy)
    again = uop_cache_info()
    assert again["misses"] == 1, "scheduler re-ran for a cached geometry"
    assert again["hits"] >= 3
    # distinct geometry is a distinct cache entry, not a collision
    tconv(jnp.ones((1, 5, 5, 2)), w, (2, 2), (1, 1), policy=policy)
    assert uop_cache_info()["misses"] == 2


def test_policy_resolution():
    """Resolution contract on a CPU host: auto → polyphase, "pallas" →
    interpret for both kernel ranks (2-D and now 3-D) with a polyphase
    fallback for ranks the kernel doesn't implement (1-D), interpret
    override implies the kernel, strict names raise on unsupported
    ranks."""
    assert DataflowPolicy().resolve(2) == "polyphase"
    assert DataflowPolicy(backend="pallas").resolve(2) == "pallas-interpret"
    assert DataflowPolicy(backend="pallas").resolve(3) == "pallas-interpret"
    assert DataflowPolicy(backend="pallas").resolve(1) == "polyphase"
    assert DataflowPolicy(interpret=True).resolve(2) == "pallas-interpret"
    assert DataflowPolicy(interpret=True).resolve(3) == "pallas-interpret"
    assert DataflowPolicy(interpret=True).resolve(1) == "polyphase"
    assert DataflowPolicy(backend="pallas",
                          interpret=True).resolve(1) == "polyphase"
    assert DataflowPolicy(backend="pallas-interpret",
                          interpret=True).resolve(3) == "pallas-interpret"
    with pytest.raises(ValueError, match="available"):
        DataflowPolicy(backend="pallus").resolve(2)
    with pytest.raises(ValueError, match="support"):
        DataflowPolicy(backend="pallas-interpret").resolve(1)
    with pytest.raises(ValueError, match="contradicts"):
        DataflowPolicy(backend="polyphase", interpret=True).resolve(2)
    with pytest.raises(ValueError, match="contradicts"):
        DataflowPolicy(backend="pallas-tpu", interpret=True).resolve(2)
    with pytest.raises(ValueError, match="contradicts"):
        DataflowPolicy(backend="pallas-interpret",
                       interpret=False).resolve(2)


def test_compile_uops_artifacts_frozen():
    u = compile_uops((4, 4), (4, 4), (2, 2), (1, 1))
    assert not u.n_taps.flags.writeable
    assert not u.k_idx.flags.writeable
    assert u.schedule.n_phases == 4


def test_gan_pallas_trains_end_to_end():
    """Acceptance: GanConfig(use_pallas=True) runs one gan_losses grad
    step through the Pallas-interpret backend with gradients matching the
    zero-insert baseline to 1e-4."""
    from repro.models.gan import GanConfig, gan_losses, init_gan

    cfg_p = GanConfig(name="dcgan", channel_scale=0.03125, use_pallas=True)
    cfg_z = GanConfig(name="dcgan", channel_scale=0.03125,
                      dataflow="zero_insert")
    assert cfg_p.policy.resolve(2) == "pallas-interpret"  # CPU test host
    g, d = init_gan(cfg_p, jax.random.PRNGKey(0))
    z = jax.random.normal(jax.random.PRNGKey(1), (2, cfg_p.z_dim))
    real = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64, 3))

    def losses(g, d, cfg):
        gl, dl, _ = gan_losses(g, d, z, real, cfg)
        return gl + dl

    (gp, dp) = jax.grad(losses, argnums=(0, 1))(g, d, cfg_p)
    (gz, dz) = jax.grad(losses, argnums=(0, 1))(g, d, cfg_z)
    for a, b in zip(jax.tree.leaves((gp, dp)), jax.tree.leaves((gz, dz))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
