"""Execute the fenced ``python`` blocks in the repo's markdown docs.

The CI docs job runs this over ``README.md`` and ``docs/*.md`` so the
examples in those pages are executed, not just read — a renamed
parameter or a drifted import fails the build instead of rotting on the
page.  The contract for doc authors:

* A block fenced exactly as ```` ```python ```` is executed.  Other
  info strings (```` ```bash ````, ```` ```text ````, bare fences) are
  ignored.
* Blocks in one file run **in order, in one shared namespace** — a
  later block may use imports and variables from an earlier one, like a
  reader following along.
* Execution happens inside a temporary working directory, so examples
  may write files (``prog.save("x.json")``) without dirtying the repo.
* Examples must be self-contained and tiny (e.g. ``channel_scale=
  0.03125``, single-digit batch sizes): the whole suite should stay in
  CI-smoke territory.
* To exempt a block that cannot run in CI, put ``<!-- docs-smoke:
  skip -->`` on its own line within the three lines above the fence.

Usage::

    PYTHONPATH=src python tools/docs_smoke.py            # README + docs/
    PYTHONPATH=src python tools/docs_smoke.py docs/serving.md
"""

from __future__ import annotations

import argparse
import contextlib
import os
import pathlib
import sys
import tempfile
import traceback

SKIP_MARKER = "docs-smoke: skip"
ROOT = pathlib.Path(__file__).resolve().parent.parent


def extract_blocks(text: str) -> list[tuple[int, str]]:
    """``(first_code_line_number, code)`` per runnable python block."""
    blocks: list[tuple[int, str]] = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        stripped = lines[i].strip()
        if stripped in ("```python", "```py"):
            skip = any(SKIP_MARKER in lines[j]
                       for j in range(max(0, i - 3), i))
            code: list[str] = []
            i += 1
            start = i + 1                      # 1-indexed first code line
            while i < len(lines) and lines[i].strip() != "```":
                code.append(lines[i])
                i += 1
            if not skip:
                blocks.append((start, "\n".join(code)))
        i += 1
    return blocks


def run_file(path: pathlib.Path) -> int:
    """Execute every runnable block of one markdown file in a shared
    namespace; returns the number of blocks run.  Raises on failure."""
    blocks = extract_blocks(path.read_text())
    namespace: dict = {"__name__": f"docs_smoke:{path.name}"}
    for line, code in blocks:
        # the synthetic filename puts doc+line in any traceback
        exec(compile(code, f"{path}:{line}", "exec"), namespace)
    return len(blocks)


def default_files() -> list[pathlib.Path]:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", type=pathlib.Path,
                    help="markdown files (default: README.md docs/*.md)")
    args = ap.parse_args(argv)
    files = [f.resolve() for f in args.files] or default_files()

    failures = 0
    with tempfile.TemporaryDirectory(prefix="docs-smoke-") as tmp, \
            contextlib.ExitStack() as stack:
        prev = os.getcwd()
        os.chdir(tmp)
        stack.callback(os.chdir, prev)
        for path in files:
            rel = path.relative_to(ROOT) if path.is_relative_to(ROOT) \
                else path
            try:
                n = run_file(path)
            except Exception:
                failures += 1
                print(f"FAIL {rel}")
                traceback.print_exc()
            else:
                print(f"ok   {rel}: {n} block(s)")
    if failures:
        print(f"\n{failures} file(s) failed")
        return 1
    print("\nall docs examples executed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
